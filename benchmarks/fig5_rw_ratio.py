"""Fig 5 — R:W-ratio sweep with store-path attribution (the paper's central
finding: achievable throughput depends on the *relation* between load and
store instructions, not raw bandwidth).

The rw_RtoW mix family (repro.bench.mixes.rw_ratio) sweeps the read:write
ratio as a first-class axis; this script is pure BenchSpec declarations —
ratio x working-set size — executed by the shared Runner.  The per-level
bandwidth-vs-ratio table comes straight from ``BenchResult.summarize``
(levels = the detected host hierarchy), NOT from hand-rolled core.analysis
table helpers: the attribution is a view on the result itself.
"""
from __future__ import annotations

import argparse
import math
from pathlib import Path

from benchmarks.common import emit
from repro.bench import RW_RATIOS, BenchSpec, Runner, rw_name
from repro.bench.result import level_band
from repro.core.buffers import hierarchy_grid
from repro.core.machine_model import detect_host

ART = Path(__file__).resolve().parents[1] / "artifacts"

#: the swept (reads, writes) ratios — the registry's canonical ladder,
#: store-heavy to load-heavy
RATIOS = RW_RATIOS


def quick_sizes(levels) -> tuple[int, ...]:
    """One band-interior working-set size per detected hierarchy level: the
    geometric mean of each level's (2x prev, 0.5x level) attribution band,
    and 2x the band floor for the unbounded DRAM level (no fixed cap — a cap
    below the floor would silently drop the DRAM row on big-LLC hosts).
    Typical cache sizes (32K/256K/...) sit exactly ON band edges, so a fixed
    size list would fall outside every band on hosts where detect_host()
    reports caches."""
    sizes, prev = [], 2 * 2**10
    for lvl in levels:
        lo, hi = level_band(lvl.size_bytes, prev)
        size = 2 * lo if math.isinf(hi) else math.sqrt(lo * hi)
        sizes.append(int(size))
        if lvl.size_bytes:
            prev = lvl.size_bytes
    if len(sizes) < 3:          # cacheless topology (DRAM-only detection)
        sizes.extend((32 * 2**10, 2 * 2**20))
    return tuple(sorted(set(sizes)))


def spec_for(quick: bool = False, smoke: bool = False) -> BenchSpec:
    ratios = ((1, 1), (2, 1), (3, 1)) if smoke else RATIOS
    mixes = tuple(rw_name(r, w) for r, w in ratios)
    if smoke:
        return BenchSpec(mixes=mixes, sizes=(32 * 2**10,), reps=2, warmup=1,
                         passes=1, tags=("fig5", "smoke"))
    if quick:
        return BenchSpec(mixes=mixes, sizes=quick_sizes(detect_host().levels),
                         reps=3, warmup=1, target_bytes=2e7, tags=("fig5",))
    return BenchSpec(mixes=mixes,
                     sizes=hierarchy_grid(hi=64 * 2**20, per_decade=4),
                     reps=10, warmup=2, target_bytes=2e8, tags=("fig5",))


def ratio_table(summary: dict) -> str:
    """Pivot ``BenchResult.summarize`` output into ratio rows x level
    columns of GB/s, with the per-level relative-to-best ratio alongside."""
    levels = list(summary)
    mixes: list[str] = []
    for cells in summary.values():
        mixes.extend(m for m in cells if m not in mixes)
    lines = [f"{'R:W':8s} " + " ".join(f"{lvl + ' GB/s':>12s} {'rel':>5s}"
                                       for lvl in levels)]
    for m in mixes:
        row = [f"{m.removeprefix('rw_').replace('to', ':'):8s}"]
        for lvl in levels:
            c = summary[lvl].get(m)
            row.append(f"{c['gbps']:12.2f} {c['rel']:5.2f}" if c else
                       f"{'-':>12s} {'-':>5s}")
        lines.append(" ".join(row))
    return "\n".join(lines)


def main(quick: bool = False, smoke: bool = False):
    res = Runner().run(spec_for(quick, smoke))
    for p in res.points:
        emit(f"fig5/{p.mix}/{p.nbytes}B", p.mean_s * 1e6, f"{p.gbps:.2f}GB/s")

    # one band in smoke mode (a single size can't attribute levels); the
    # detected host hierarchy otherwise
    levels = None if smoke else detect_host().levels
    summary = res.summarize(levels=levels)
    print()
    print(ratio_table(summary))

    if not smoke:
        ART.mkdir(exist_ok=True)
        res.to_json(ART / "fig5_rw_ratio.json")
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny size, 3 ratios — the CI smoke gate")
    main(**vars(ap.parse_args()))
