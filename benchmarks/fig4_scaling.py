"""Fig 4 — multi-device scaling + STREAM-triad comparison.

MUST run as its own process: forces 8 host devices before jax init.  On TPU
hardware the same code produces the real per-chip HBM scaling curve (the
paper's CMG saturation study); on host the 8 'devices' share one socket so the
curve saturating early IS the expected result (shared-bandwidth NUMA analogue).

Everything here is a BenchSpec through ``repro.bench``: the scaling curve is
the ``sharded`` backend swept over the ``devices`` knob (one spec per device
count, merged by ``run_many``), with per-count speedup read off
``BenchResult.baseline_relative``; the triad reference (the paper compares
against STREAM on A64FX) is the registry's ``triad`` mix as a one-size spec.
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse           # noqa: E402

from benchmarks.common import emit                       # noqa: E402
from repro.bench import BenchSpec, Runner                # noqa: E402


def main(quick: bool = False):
    per_dev = 2 * 2**20 if quick else 16 * 2**20
    runner = Runner()
    specs = [BenchSpec(mixes=("load_sum",), sizes=(per_dev * k,),
                       backend="sharded", devices=k, passes=4,
                       reps=4 if quick else 8, warmup=2)
             for k in (1, 2, 4, 8)]
    res = runner.run_many(specs)
    for p, speedup in res.baseline_relative(group_key=lambda p: p.mix):
        emit(f"fig4/devices{p.devices}", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;speedup={speedup:.2f}x")

    # STREAM triad reference (the paper compares against STREAM on A64FX)
    spec = BenchSpec(mixes=("triad",), sizes=(per_dev,), reps=4, warmup=2,
                     target_bytes=5e7)
    t = runner.run(spec).points[0]
    emit("fig4/stream_triad_1dev", t.mean_s * 1e6, f"{t.gbps:.2f}GB/s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
