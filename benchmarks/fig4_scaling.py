"""Fig 4 — multi-device scaling + STREAM-triad comparison.

MUST run as its own process: forces 8 host devices before jax init.  On TPU
hardware the same code produces the real per-chip HBM scaling curve (the
paper's CMG saturation study); on host the 8 'devices' share one socket so the
curve saturating early IS the expected result (shared-bandwidth NUMA analogue).

Everything here is a BenchSpec through ``repro.bench``: the scaling curve is
the ``sharded`` backend swept over the ``devices`` knob (one spec per device
count, merged by ``run_many``), with per-count speedup read off
``BenchResult.baseline_relative``; the triad reference (the paper compares
against STREAM on A64FX) is the registry's ``triad`` mix as a one-size spec.

``--distributed`` takes the same sweep multi-process: the script respawns
itself as ``--processes`` coordinated workers (repro.bench.distributed's
launcher, forced host devices per process), each running the identical
sweep on the ``distributed`` backend over the **global** mesh; process 0
gathers and emits.  ``processes x devices-per-process`` simulated hosts
reproduce the paper's scaling study past one machine — on a real cluster,
start one worker per host with the REPRO_* env set instead of respawning.
"""
import os
import sys

#: set in workers by the launcher (or on the hosts of a real cluster, where
#: JAX's own env names are equally valid — see repro.bench.distributed);
#: when active, jax.distributed (not XLA_FLAGS below) decides the topology.
#: The coordinator address alone marks a worker — keying on a process COUNT
#: would send a --processes 1 child back into the launcher branch, an
#: infinite respawn chain.  Checked without importing repro so it runs
#: before any jax setup.
_UNDER_LAUNCHER = any(
    os.environ.get(k) for k in ("REPRO_COORDINATOR", "JAX_COORDINATOR_ADDRESS",
                                "REPRO_NUM_PROCESSES", "JAX_NUM_PROCESSES"))

if __name__ == "__main__" and not _UNDER_LAUNCHER:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse           # noqa: E402

from benchmarks.common import emit                       # noqa: E402


def run_curve(backend: str, per_dev: int, counts, reps: int):
    """The devices sweep + emit lines (shared by both modes).  Under a
    multi-process run, only process 0 emits (it holds the gathered result);
    the sweep itself is identical SPMD work on every process."""
    from repro.bench import BenchSpec, Runner
    from repro.bench import distributed as dist
    runner = Runner()
    specs = [BenchSpec(mixes=("load_sum",), sizes=(per_dev * k,),
                       backend=backend, devices=k, passes=4,
                       reps=reps, warmup=2)
             for k in counts]
    res = dist.gather_result(runner.run_many(specs))

    # STREAM triad reference (the paper compares against STREAM on A64FX):
    # plain xla single-process (the historical baseline); distributed mode
    # keeps all processes in the computation on the smallest covering mesh.
    # NB every process must reach this point — the measurement is SPMD; only
    # the emission below is gated on process 0.
    t_backend, t_devs = (("xla", 1) if backend == "sharded"
                         else (backend, min(counts)))
    # sized per device like the sweep, so the rows always shard evenly
    spec = BenchSpec(mixes=("triad",), sizes=(per_dev * t_devs,), reps=reps,
                     warmup=2, backend=t_backend, devices=t_devs,
                     target_bytes=5e7)
    t = dist.gather_result(runner.run(spec)).points[0]

    if not dist.is_primary():
        return
    tag = "fig4_dist" if backend == "distributed" else "fig4"
    pc = res.machine.get("process_count", 1)
    for p, speedup in res.baseline_relative(group_key=lambda p: p.mix):
        emit(f"{tag}/devices{p.devices}", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;speedup={speedup:.2f}x;processes={pc}")
    emit(f"{tag}/stream_triad_{t_devs}dev", t.mean_s * 1e6,
         f"{t.gbps:.2f}GB/s")


def main(quick: bool = False, smoke: bool = False, distributed: bool = False,
         processes: int = 2, devices_per_process: int = 2) -> int:
    per_dev = 2 * 2**20 if quick else 16 * 2**20
    if smoke:
        per_dev = 256 * 2**10
    reps = 2 if smoke else (4 if quick else 8)

    if distributed and not _UNDER_LAUNCHER:
        # launcher role: respawn this script as N coordinated workers; their
        # global mesh has processes * devices_per_process devices
        if processes < 2:
            print("error: --distributed needs --processes >= 2 "
                  "(use the plain sharded mode for one process)",
                  file=sys.stderr)
            return 2
        from repro.bench.distributed import launch_local
        argv = [sys.executable, "-m", "benchmarks.fig4_scaling",
                "--distributed", "--processes", str(processes),
                "--devices-per-process", str(devices_per_process)]
        argv += ["--quick"] if quick else []
        argv += ["--smoke"] if smoke else []
        return launch_local(argv, processes=processes,
                            devices_per_process=devices_per_process,
                            stream_to=sys.stdout)

    if distributed:                     # worker role (spawned above)
        from repro.bench import distributed as dist
        dist.ensure_initialized()
        # the mesh must give every process a shard; the shared helper also
        # falls back to the full global mesh when no ladder value qualifies
        run_curve("distributed", per_dev, dist.covering_device_counts(),
                  reps)
        return 0

    import jax
    from repro.bench.distributed import DEVICE_LADDER
    run_curve("sharded", per_dev,
              tuple(k for k in DEVICE_LADDER if k <= jax.device_count()),
              reps)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / 2 reps (CI gate)")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-process mode: respawns itself via the "
                         "repro.bench launcher (simulated multi-host)")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", dest="devices_per_process",
                    type=int, default=2)
    sys.exit(main(**vars(ap.parse_args())))
