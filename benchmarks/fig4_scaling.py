"""Fig 4 — multi-device scaling + STREAM-triad comparison.

MUST run as its own process: forces 8 host devices before jax init.  On TPU
hardware the same code produces the real per-chip HBM scaling curve (the
paper's CMG saturation study); on host the 8 'devices' share one socket so the
curve saturating early IS the expected result (shared-bandwidth NUMA analogue).

The triad kernel is the registry's ``triad`` mix (STREAM comparison on A64FX
in the paper) declared as a one-size BenchSpec; the multi-device curve stays
in core.scaling (its own subsystem, pending a sharded backend).
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse           # noqa: E402

from benchmarks.common import emit                       # noqa: E402
from repro.bench import BenchSpec, Runner                # noqa: E402
from repro.core.scaling import scaling_curve             # noqa: E402


def main(quick: bool = False):
    per_dev = 2 * 2**20 if quick else 16 * 2**20
    pts = scaling_curve(per_dev, device_counts=[1, 2, 4, 8],
                        passes=4, reps=4 if quick else 8)
    for p in pts:
        emit(f"fig4/devices{p.devices}", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;speedup={p.speedup:.2f}x")

    # STREAM triad reference (the paper compares against STREAM on A64FX)
    spec = BenchSpec(mixes=("triad",), sizes=(per_dev,), reps=4, warmup=2,
                     target_bytes=5e7)
    t = Runner().run(spec).points[0]
    emit("fig4/stream_triad_1dev", t.mean_s * 1e6, f"{t.gbps:.2f}GB/s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
