"""Fig 4 — multi-device scaling + STREAM-triad comparison.

MUST run as its own process: forces 8 host devices before jax init.  On TPU
hardware the same code produces the real per-chip HBM scaling curve (the
paper's CMG saturation study); on host the 8 'devices' share one socket so the
curve saturating early IS the expected result (shared-bandwidth NUMA analogue).
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse           # noqa: E402
from functools import partial  # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402

from benchmarks.common import emit                       # noqa: E402
from repro.core import buffers, timing                   # noqa: E402
from repro.core.scaling import scaling_curve             # noqa: E402


@partial(jax.jit, static_argnames=("passes",))
def stream_triad(a, b, c, passes: int):
    def body(_, carry):
        a, acc = carry
        a = b + 1.5 * c + a * 1e-30          # triad with self-dependence
        return (a, acc + a[0, 0].astype(jnp.float32))
    a, acc = jax.lax.fori_loop(0, passes, body, (a, jnp.float32(0)))
    return acc


def main(quick: bool = False):
    per_dev = 2 * 2**20 if quick else 16 * 2**20
    pts = scaling_curve(per_dev, device_counts=[1, 2, 4, 8],
                        passes=4, reps=4 if quick else 8)
    for p in pts:
        emit(f"fig4/devices{p.devices}", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;speedup={p.speedup:.2f}x")

    # STREAM triad reference (the paper compares against STREAM on A64FX)
    x = buffers.working_set(per_dev)
    b, c = x, x * 0.5
    a = jnp.zeros_like(x)
    passes = max(1, int(5e7 / (x.size * 4)))
    t = timing.time_fn(lambda: stream_triad(a, b, c, passes), reps=4,
                       warmup=2, bytes_per_call=float(3 * x.size * 4 * passes))
    emit("fig4/stream_triad_1dev", t.mean_s * 1e6, f"{t.gbps:.2f}GB/s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
