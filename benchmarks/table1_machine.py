"""Table 1 — system specification table: documented peaks (paper systems +
TPU v5e target) vs what this harness measures on the host."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit
from repro.core.machine_model import (A64FX, ALTRA, THUNDERX2, TPU_V5E,
                                      detect_host)

ART = Path(__file__).resolve().parents[1] / "artifacts"


def show(hw, measured=None):
    print(f"\n## {hw.name}")
    if hw.frequency_hz:
        print(f"  frequency: {hw.frequency_hz/1e9:.1f} GHz")
    if hw.peak_flops:
        print(f"  peak compute: {hw.peak_flops/1e12:.1f} TFLOP/s")
    for lvl in hw.levels:
        size = f"{lvl.size_bytes/2**10:.0f} KiB" if lvl.size_bytes and \
            lvl.size_bytes < 2**20 else \
            (f"{lvl.size_bytes/2**20:.0f} MiB" if lvl.size_bytes else "-")
        bw = f"{lvl.read_bw/1e9:.1f} GB/s" if lvl.read_bw else "undocumented"
        meas = ""
        if measured and lvl.name in measured:
            best = max(measured[lvl.name].values())
            meas = f"  measured(best mix): {best:.1f} GB/s"
        print(f"  {lvl.name:6s} size={size:>9s}  documented={bw}{meas}")
    if hw.link_bw:
        print(f"  interconnect: {hw.link_bw/1e9:.0f} GB/s per link")
    if hw.notes:
        print(f"  notes: {hw.notes}")


def main(quick: bool = False):
    measured = None
    mm_path = ART / "machine_model_host.json"
    if mm_path.exists():
        measured = json.loads(mm_path.read_text()).get("level_bw")
    for hw in (TPU_V5E, A64FX, ALTRA, THUNDERX2):
        show(hw)
    show(detect_host(), measured)
    emit("table1/systems", 0.0, "5 systems (3 paper + v5e target + host)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
