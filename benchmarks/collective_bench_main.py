"""ICI-analogue collective throughput (all-reduce / all-gather / reduce-scatter
/ all-to-all / ppermute) on an 8-device host mesh.  Own process: forces the
device count before jax init.  On TPU the same code measures real ICI links."""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse           # noqa: E402

from benchmarks.common import emit                       # noqa: E402


def main(quick: bool = False):
    from repro.core.collective_bench import bench_all
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    res = bench_all(mesh, nbytes=(1 if quick else 8) * 2**20,
                    reps=4 if quick else 10)
    for r in res:
        emit(f"collectives/{r.op}/{r.axis}{r.group_size}", r.mean_s * 1e6,
             f"algo={r.algo_gbps:.2f}GB/s;link={r.link_gbps:.2f}GB/s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
