"""Benchmark runner — one entry per paper table/figure.

``python -m benchmarks.run``         quick pass of every benchmark
``python -m benchmarks.run --full``  full sweep (slower)

Every figure script is a BenchSpec declaration executed by the shared
``repro.bench`` Runner (``python -m repro.bench`` is the standalone CLI; the
``bench`` entry here smoke-runs it).  Output: ``name,us_per_call,derived``
CSV lines (+ analysis tables).  fig4 and the collective bench run in
subprocesses (they force multi-device jax before init); everything else runs
in-process.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _subproc(mod: str, quick: bool):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = f"{ROOT}/src:{ROOT}"
    cmd = [sys.executable, "-m", mod] + (["--quick"] if quick else [])
    r = subprocess.run(cmd, env=env, cwd=ROOT, text=True, capture_output=True,
                       timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stdout.write(f"# {mod} FAILED\n{r.stderr[-2000:]}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: bench,fig1,fig2,fig3,fig4,fig5,fig6,"
                         "fig7,table1,collectives,roofline")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("# Arm-membench (TPU port) benchmark suite")
    print("# name,us_per_call,derived")

    if want("bench"):
        print("\n## bench: unified experiment API smoke (python -m repro.bench)")
        from repro.bench.cli import main as bench_main
        (ROOT / "artifacts").mkdir(exist_ok=True)
        bench_main(["run", "--quick", "--out",
                    str(ROOT / "artifacts" / "bench_quick.json")])
    if want("fig2"):
        print("\n## fig2/5/6: hierarchy sweep x instruction mix (host measured)")
        from benchmarks import fig2_hierarchy
        fig2_hierarchy.main(quick=quick)
    if want("fig1"):
        print("\n## fig1: addressing-mode / stream-count overhead")
        from benchmarks import fig1_addressing
        fig1_addressing.main(quick=quick)
    if want("fig3"):
        print("\n## fig3: block-shape (registers-per-load) sweep")
        from benchmarks import fig3_blockshape
        fig3_blockshape.main(quick=quick)
    if want("fig4"):
        print("\n## fig4: device scaling + STREAM triad (8-device subprocess)")
        _subproc("benchmarks.fig4_scaling", quick)
    if want("fig5"):
        print("\n## fig5: R:W-ratio sweep, store-path attribution (rw family)")
        from benchmarks import fig5_rw_ratio
        fig5_rw_ratio.main(quick=quick)
    if want("fig6"):
        print("\n## fig6: instruction-stream classification "
              "(bandwidth- vs issue-bound)")
        from benchmarks import fig6_istream
        fig6_istream.main(quick=quick)
    if want("fig7"):
        print("\n## fig7: loaded-latency surface (bandwidth-latency curves)")
        from benchmarks import fig7_loaded_latency
        fig7_loaded_latency.main(quick=quick)
    if want("collectives"):
        print("\n## collectives: ICI-analogue link throughput (subprocess)")
        _subproc("benchmarks.collective_bench_main", quick)
    if want("table1"):
        print("\n## table1: machine models (documented vs measured)")
        from benchmarks import table1_machine
        table1_machine.main(quick=quick)
    if want("roofline"):
        print("\n## roofline: 40-cell dry-run table (reads artifacts/dryrun)")
        from benchmarks import roofline_table
        roofline_table.main()


if __name__ == "__main__":
    main()
