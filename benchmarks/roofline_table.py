"""§Roofline table generator — renders artifacts/dryrun/*.json as markdown.

One row per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, HBM fit, and a one-line
'what would move the dominant term' note.
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
PROBE = Path(__file__).resolve().parents[1] / "artifacts" / "probe"

NOTES = {
    ("compute",): "raise arithmetic intensity: larger kv_block / fused kernels",
    ("memory",): "cut bytes: fp8/int8 weights, fused norms, better remat policy",
    ("collective",): "cut wire bytes: bf16 psum, a2a dispatch, overlap via LHS",
}


def load(variant: str = "baseline"):
    """Prefer probe records (correct loop accounting) for the roofline terms;
    merge the rolled dry-run's memory_analysis fields (fit proof)."""
    rows = []
    for f in sorted(glob.glob(str(ART / f"*__{variant}.json"))):
        d = json.loads(Path(f).read_text())
        p = PROBE / Path(f).name
        if p.exists():
            pd = json.loads(p.read_text())
            if pd.get("status") == "ok":
                keep = {k: d.get(k) for k in ("peak_device_bytes", "fits_hbm",
                                              "arg_bytes", "temp_bytes")}
                d = {**d, **pd, **{k: v for k, v in keep.items()
                                   if v is not None}}
        rows.append(d)
    return rows


def render(rows, show_skips=False):
    hdr = ("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | useful_flops | peak GiB | fits |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] == "skipped":
            if show_skips:
                out.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | - | - "
                           f"| skipped | - | - | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR: "
                       f"{r['error'][:40]} | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r.get('useful_flop_ratio', 0):.2f} "
            f"| {r['peak_device_bytes']/2**30:.2f} "
            f"| {'Y' if r.get('fits_hbm') else 'N'} |")
    return "\n".join(out)


def main(variant: str = "baseline", quick: bool = False):
    rows = load(variant)
    print(render(rows, show_skips=True))
    ok = [r for r in rows if r["status"] == "ok"]
    emit("roofline/cells", 0.0,
         f"{len(ok)} compiled cells, variant={variant}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--quick", action="store_true")
    main(**{k: v for k, v in vars(ap.parse_args()).items()})
