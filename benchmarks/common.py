"""Shared benchmark helpers: CSV emission in the required format."""
from __future__ import annotations

import sys


def emit(name: str, us_per_call: float, derived: str):
    """Required format: name,us_per_call,derived"""
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()
