"""Fig 3 — registers-per-load-instruction (LD1D/LD2D/LD4D) => rows-per-block.

Host analogue: the reduction walks the buffer in blocks of R rows per step; R
is the LD1/2/4 'registers per instruction' analogue.  The Pallas membench
kernel sweeps the same knob as a real BlockSpec (core/autotune.py); here the
host table is *measured* and the Pallas path is verified numerically.
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import buffers, timing


@partial(jax.jit, static_argnames=("rows", "passes"))
def blocked_sum(x, rows: int, passes: int):
    n_blocks = x.shape[0] // rows

    def body(_, carry):
        x, acc = carry

        def inner(i, a):
            blk = jax.lax.dynamic_slice_in_dim(x, i * rows, rows, axis=0)
            return a + jnp.sum(blk, dtype=jnp.float32)

        s = jax.lax.fori_loop(0, n_blocks, inner, jnp.float32(0))
        eps = (s * 1e-30).astype(x.dtype).reshape(())
        return (x.at[0, 0].add(eps), acc + s)

    _, acc = jax.lax.fori_loop(0, passes, body, (x, jnp.float32(0)))
    return acc


def main(quick: bool = False):
    nbytes = 4 * 2**20 if quick else 16 * 2**20
    x = buffers.working_set(nbytes)
    real = x.size * x.dtype.itemsize
    passes = max(1, int((5e7 if quick else 2e8) / real))
    reps = 5 if quick else 10
    rows_list = (8, 16, 32, 128) if quick else (8, 16, 32, 64, 128, 256, 512)
    best = (None, 0.0)
    for rows in rows_list:
        if x.shape[0] % rows:
            continue
        t = timing.time_fn(lambda: blocked_sum(x, rows, passes), reps=reps,
                           warmup=2, bytes_per_call=float(real * passes))
        emit(f"fig3/rows{rows}/{real}B", t.mean_s * 1e6, f"{t.gbps:.2f}GB/s")
        if t.gbps > best[1]:
            best = (rows, t.gbps)
    print(f"# best block rows on this host: {best[0]} ({best[1]:.1f} GB/s)")

    # Pallas path: numerics check via interpret mode (structure, not time)
    from repro.kernels.membench import ops as mb_ops
    from repro.kernels.membench.ref import reference
    xs = buffers.working_set(64 * 2**10)
    for rows in (8, 32, 128):
        out = float(mb_ops.make_kernel("load_sum", block_rows=rows)(xs))
        ref = float(reference("load_sum", xs))
        assert abs(out - ref) < 1e-2, (rows, out, ref)
    print("# pallas block-shape kernels verified vs oracle (interpret mode)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
