"""Fig 3 — registers-per-load-instruction (LD1D/LD2D/LD4D) => rows-per-block.

Host analogue: the reduction walks the buffer in blocks of R rows per step; R
is the LD1/2/4 'registers per instruction' analogue (the blocked kernel lives
in core.instruction_mix).  The script declares one BenchSpec per block shape
(block_rows = C4 knob) for the measured host table, then runs the *same*
specs through the Pallas backend in interpret mode and verifies the kernels
against the jnp oracle — one mix registry, two backends.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.bench import BenchSpec, BenchSpecError, Runner


def main(quick: bool = False):
    nbytes = 4 * 2**20 if quick else 16 * 2**20
    rows_list = (8, 16, 32, 128) if quick else (8, 16, 32, 64, 128, 256, 512)
    base = BenchSpec(mixes=("load_sum",), sizes=(nbytes,),
                     reps=5 if quick else 10, warmup=2,
                     target_bytes=5e7 if quick else 2e8)

    runner = Runner()
    best = (None, 0.0)
    for rows in rows_list:
        try:
            res = runner.run(base.replace(block_rows=rows))
        except BenchSpecError:     # rows not dividing this working set
            continue
        p = res.points[0]
        emit(f"fig3/rows{rows}/{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s")
        if p.gbps > best[1]:
            best = (rows, p.gbps)
    print(f"# best block rows on this host: {best[0]} ({best[1]:.1f} GB/s)")

    # Pallas path: same spec shape on the pallas backend, numerics vs oracle
    # (interpret mode validates structure, not time)
    from repro.kernels.membench import ops as mb_ops
    from repro.kernels.membench.ref import reference
    from repro.core import buffers
    small = base.replace(sizes=(64 * 2**10,), backend="pallas", passes=1,
                         reps=2, warmup=1)
    xs = buffers.working_set(64 * 2**10)
    for rows in (8, 32, 128):
        runner.run(small.replace(block_rows=rows))      # runs through Runner
        out = float(mb_ops.make_kernel("load_sum", block_rows=rows)(xs))
        ref = float(reference("load_sum", xs))
        assert abs(out - ref) < 1e-2, (rows, out, ref)
    print("# pallas block-shape kernels verified vs oracle (interpret mode)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
