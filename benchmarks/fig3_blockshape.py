"""Fig 3 — registers-per-load-instruction (LD1D/LD2D/LD4D) => rows-per-block.

Host analogue: the reduction walks the buffer in blocks of R rows per step; R
is the LD1/2/4 'registers per instruction' analogue (the blocked kernel lives
in core.instruction_mix).  The script declares one BenchSpec per block shape
(block_rows = C4 knob) for the measured host table, then runs the *same*
specs through the Pallas backend in interpret mode and verifies the kernels
against the jnp oracle — one mix registry, two backends.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.audit import validate_ecm
from repro.bench import BenchSpec, BenchSpecError, Runner
from repro.characterize.fit import FittedMachineModel, LevelFit
from repro.core import buffers
from repro.istream import ProfileCache, analyze_case, fit_issue_rate


def main(quick: bool = False):
    nbytes = 4 * 2**20 if quick else 16 * 2**20
    rows_list = (8, 16, 32, 128) if quick else (8, 16, 32, 64, 128, 256, 512)
    base = BenchSpec(mixes=("load_sum",), sizes=(nbytes,),
                     reps=5 if quick else 10, warmup=2,
                     target_bytes=5e7 if quick else 2e8)

    runner = Runner()
    best = (None, 0.0)
    pairs = []          # (BenchPoint, InstructionProfile) across the sweep
    cache = ProfileCache()
    shape = buffers.working_set_shape(nbytes)
    for rows in rows_list:
        try:
            spec = base.replace(block_rows=rows)
            res = runner.run(spec)
        except BenchSpecError:     # rows not dividing this working set
            continue
        p = res.points[0]
        emit(f"fig3/rows{rows}/{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s")
        try:
            pairs.append((p, analyze_case(spec, "load_sum", shape, "float32",
                                          p.passes, runner=runner,
                                          cache=cache)))
        except Exception as e:     # prediction is a bonus, never blocks fig3
            print(f"# ecm: profile extraction failed at rows={rows}: {e}")
        if p.gbps > best[1]:
            best = (rows, p.gbps)
    print(f"# best block rows on this host: {best[0]} ({best[1]:.1f} GB/s)")

    # ECM predicted-vs-measured over the very sweep just timed: the sweep
    # self-calibrates a one-level model (best sustained transfer rate +
    # fitted issue rate) and the predictor must then reproduce each point's
    # time from its compiled profile alone.  The transfer term is calibrated
    # in OBSERVED compiled bytes/s, not declared GB/s — the blocked host
    # reduction materializes per-partial sums (the audit's documented
    # xla/load_sum blocked waiver), so declared-byte bandwidth would
    # understate what the memory path actually sustained.
    if pairs:
        def _obs_bw(p, prof):
            per_pass = (prof.per_iter["loads"] + prof.per_iter["stores"]) \
                / max(prof.unroll, 1) * 4
            return per_pass * p.passes / p.mean_s
        model = FittedMachineModel(
            name="fig3-self-calibrated",
            levels=(LevelFit(
                name="mem", capacity_bytes=None, capacity_ci=None,
                bandwidth={"load_sum": {
                    "gbps": max(_obs_bw(p, pr) for p, pr in pairs) / 1e9,
                    "ci": None, "n": len(pairs)}}),),
            issue={"rate_elems_per_s": fit_issue_rate(pairs)})
        val = validate_ecm(pairs, model)
        for r in val["rows"]:
            emit(f"fig3/ecm/rows{r['knobs']['block_rows']}",
                 r["predicted_s"] * 1e6,
                 f"meas={r['measured_s'] * 1e6:.1f}us "
                 f"err={r['rel_err'] * 100:+.1f}% {r['bound']}-bound")
        print(f"# ecm predicted-vs-measured over {val['n']} block shapes: "
              f"median |rel err| {val['median_abs_rel_err'] * 100:.1f}%, "
              f"max {val['max_abs_rel_err'] * 100:.1f}%")

    # Pallas path: same spec shape on the pallas backend, numerics vs oracle
    # (interpret mode validates structure, not time)
    from repro.kernels.membench import ops as mb_ops
    from repro.kernels.membench.ref import reference
    small = base.replace(sizes=(64 * 2**10,), backend="pallas", passes=1,
                         reps=2, warmup=1)
    xs = buffers.working_set(64 * 2**10)
    for rows in (8, 32, 128):
        runner.run(small.replace(block_rows=rows))      # runs through Runner
        out = float(mb_ops.make_kernel("load_sum", block_rows=rows)(xs))
        ref = float(reference("load_sum", xs))
        assert abs(out - ref) < 1e-2, (rows, out, ref)
    print("# pallas block-shape kernels verified vs oracle (interpret mode)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
