"""Fig 6 — bandwidth-bound vs issue-bound classification (repro.istream).

The paper's decode-width finding as a table: sweep the instruction-stream
knobs (unroll x interleave) over lean and store-mixed kernels on both
backends, extract each compiled case's HLO instruction profile, and label
every measured point bandwidth-bound or issue-bound with a confidence
margin.  Cache-resident sizes should trend issue-bound (the working set is
cheap to move, the issue path is the limiter); DRAM-resident sizes
bandwidth-bound.

Caption note: since the rotating-carry fix, carried-mix (copy / triad /
rw) unroll columns are **absolute GB/s** — the accounting auditor enforces
that unroll=u moves u x one sweep's declared traffic, and each table row's
``traffic`` column records that provenance (``audited``).  Only rows with
a documented waiver (e.g. chunked interleave>1) remain issue-axis shapes.

This script is a thin declaration over ``repro.istream.run_istream`` — the
sweep grid is the only thing decided here.  A fitted machine model
(``python -m repro.bench characterize --out model.json``) sharpens the
bandwidth side of the classification; without one the sweep
self-calibrates from its own fastest points.
"""
from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import emit
from repro.istream import run_istream

ART = Path(__file__).resolve().parents[1] / "artifacts"


def grid(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        return dict(smoke=True)
    if quick:
        return dict(sizes=(1 << 16, 1 << 20, 1 << 23),
                    unrolls=(1, 2), interleaves=(1, 2), reps=3)
    return dict(sizes=(1 << 16, 1 << 20, 1 << 24, 1 << 26),
                unrolls=(1, 2, 4), interleaves=(1, 2, 4), reps=5)


def main(quick: bool = False, smoke: bool = False, out: str | None = None,
         model: str | None = None):
    kw = grid(quick, smoke)
    if model:
        from repro.characterize.fit import FittedMachineModel
        kw["model"] = FittedMachineModel.from_json(model)
    report = run_istream(**kw)
    for p in sorted(report.result.points,
                    key=lambda p: (p.backend, p.mix, p.nbytes,
                                   p.unroll, p.interleave)):
        info = p.istream or {}
        emit(f"fig6/{p.backend}/{p.mix}/u{p.unroll}i{p.interleave}/"
             f"{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;{info.get('label', 'unclassified')}")
    print()
    print(report.table)

    if out:
        report.result.to_json(out)
        print(f"# saved {len(report.result.points)} classified points "
              f"(schema v{report.result.schema_version}) -> {out}")
    elif not smoke:
        ART.mkdir(exist_ok=True)
        report.result.to_json(ART / "fig6_istream.json")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale grid — the CI smoke gate")
    ap.add_argument("--out", default=None,
                    help="write the classified result JSON here")
    ap.add_argument("--model", default=None,
                    help="FittedMachineModel JSON for bandwidth lookup")
    main(**vars(ap.parse_args()))
