"""Fig 1 — addressing-mode overhead (post-increment vs manual multi-pointer).

Host analogue: contiguous single-stream reduction vs S interleaved strided
streams (stride = S x lane row).  On Arm the post-increment costs extra AGU
uOPs; on a cached host CPU the strided walk defeats the linear prefetcher the
same way — both are 'the address pattern, not the data volume, sets the rate'.
The Pallas kernel exposes the same knob (streams=) natively for TPU runs.
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import buffers, timing


@partial(jax.jit, static_argnames=("streams", "passes"))
def strided_sum(x, streams: int, passes: int):
    def body(_, carry):
        x, acc = carry
        s = jnp.float32(0)
        for k in range(streams):               # S interleaved address streams
            s = s + jnp.sum(x[k::streams], dtype=jnp.float32)
        eps = (s * 1e-30).astype(x.dtype).reshape(())
        return (x.at[0, 0].add(eps), acc + s)
    _, acc = jax.lax.fori_loop(0, passes, body, (x, jnp.float32(0)))
    return acc


def main(quick: bool = False):
    sizes = [32 * 2**10, 1 * 2**20, 32 * 2**20] if quick else \
        [32 * 2**10, 256 * 2**10, 1 * 2**20, 8 * 2**20, 32 * 2**20, 128 * 2**20]
    reps = 5 if quick else 10
    for nbytes in sizes:
        x = buffers.working_set(nbytes)
        real = x.size * x.dtype.itemsize
        passes = max(1, int((5e7 if quick else 2e8) / real))
        base = None
        for streams in (1, 2, 4, 8):
            t = timing.time_fn(lambda: strided_sum(x, streams, passes),
                               reps=reps, warmup=2,
                               bytes_per_call=float(real * passes))
            rel = t.gbps / base if base else 1.0
            base = base or t.gbps
            emit(f"fig1/streams{streams}/{real}B", t.mean_s * 1e6,
                 f"{t.gbps:.2f}GB/s;rel={rel:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
