"""Fig 1 — addressing-mode overhead (post-increment vs manual multi-pointer).

Host analogue: contiguous single-stream reduction vs S interleaved strided
streams (stride = S x lane row).  On Arm the post-increment costs extra AGU
uOPs; on a cached host CPU the strided walk defeats the linear prefetcher the
same way — both are 'the address pattern, not the data volume, sets the rate'.

The strided kernel lives in core.instruction_mix (k_strided_sum); this script
is just the BenchSpec declaration (streams = C3 knob) plus the figure's emit
lines.  Relative throughput anchors on the streams=1 point per size via
BenchResult.baseline_relative — an explicit presence check, so a 0.0 first
measurement can no longer silently re-anchor the baseline.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.bench import BenchSpec, Runner
from repro.core.buffers import hierarchy_grid

STREAM_COUNTS = (1, 2, 4, 8)


def main(quick: bool = False, out: str | None = None):
    # shared grid constructor (core.buffers): the quick ladder, or a sparse
    # log grid across the full hierarchy span — per-script size lists are gone
    sizes = hierarchy_grid(quick=True) if quick else \
        hierarchy_grid(per_decade=2)
    base = BenchSpec(mixes=("load_sum",), sizes=sizes,
                     reps=5 if quick else 10, warmup=2,
                     target_bytes=5e7 if quick else 2e8)

    res = Runner().run_many(
        [base.replace(streams=s) for s in STREAM_COUNTS])

    rel = dict(res.baseline_relative(group_key=lambda p: p.nbytes,
                                     is_baseline=lambda p: p.streams == 1))
    for p in sorted(res.points, key=lambda p: (p.nbytes, p.streams)):
        emit(f"fig1/streams{p.streams}/{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s;rel={rel[p]:.3f}")
    if out:
        res.to_json(out)
        print(f"# saved {len(res.points)} points "
              f"(schema v{res.schema_version}) -> {out}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="write result JSON here")
    main(**vars(ap.parse_args()))
