"""Fig 2/5/6 — memory-hierarchy throughput sweep under instruction mixes.

This *measures the host CPU* (its L1/L2/L3/DRAM) — the same experiment the
paper runs on A64FX/Altra/ThunderX2, proving the harness end-to-end.  The
script is one BenchSpec declaration; measurement goes through the bench
Runner, and the per-level table and mix-penalty ratios (the paper's FADD 69% /
NOP 88% / LOAD 99% analysis) are derived by core.analysis from the
schema-versioned BenchResult.
"""
from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import emit
from repro.bench import BenchSpec, Runner
from repro.core import analysis
from repro.core.buffers import hierarchy_grid
from repro.core.machine_model import detect_host

ART = Path(__file__).resolve().parents[1] / "artifacts"


def spec_for(quick: bool) -> BenchSpec:
    if quick:
        return BenchSpec(
            mixes=("load_sum", "copy", "fma_8"),
            sizes=hierarchy_grid(quick=True),
            reps=5, warmup=2, target_bytes=5e7)
    return BenchSpec(
        mixes=("load_sum", "copy", "fma_2", "fma_8", "fma_32"),
        sizes=hierarchy_grid(),
        reps=10, warmup=2, target_bytes=2e8)


def main(quick: bool = False):
    res = Runner().run(spec_for(quick))
    host = detect_host()
    model = analysis.build_machine_model(res, host)

    ART.mkdir(exist_ok=True)
    res.to_json(ART / "fig2_sweep.json")
    model.to_json(ART / "machine_model_host.json")

    for p in res.points:
        emit(f"fig2/{p.mix}/{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s")
    print()
    print(analysis.format_table(model.level_bw, model.mix_penalty))
    if model.ridge_flops_per_byte:
        print(f"\nmeasured ridge point: {model.ridge_flops_per_byte:.1f} flop/B")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
