"""Fig 2/5/6 — memory-hierarchy throughput sweep under instruction mixes.

This *measures the host CPU* (its L1/L2/L3/DRAM) — the same experiment the
paper runs on A64FX/Altra/ThunderX2, proving the harness end-to-end.  The
per-level table and the mix-penalty ratios (the paper's FADD 69% / NOP 88% /
LOAD 99% analysis) are derived by core.analysis.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit
from repro.core import analysis, sweep
from repro.core.buffers import sizes_logspace
from repro.core.machine_model import detect_host

ART = Path(__file__).resolve().parents[1] / "artifacts"


def main(quick: bool = False):
    if quick:
        sizes = [32 * 2**10, 256 * 2**10, 2 * 2**20, 16 * 2**20]
        mixes = ["load_sum", "copy", "fma_8"]
        reps, target = 5, 5e7
    else:
        sizes = sizes_logspace(16 * 2**10, 128 * 2**20, per_decade=6)
        mixes = ["load_sum", "copy", "fma_2", "fma_8", "fma_32"]
        reps, target = 10, 2e8

    res = sweep.run_sweep(sizes=sizes, mix_names=mixes, reps=reps,
                          target_bytes=target)
    host = detect_host()
    model = analysis.build_machine_model(res, host)

    ART.mkdir(exist_ok=True)
    res.to_json(ART / "fig2_sweep.json")
    model.to_json(ART / "machine_model_host.json")

    for p in res.points:
        emit(f"fig2/{p.mix}/{p.nbytes}B", p.mean_s * 1e6,
             f"{p.gbps:.2f}GB/s")
    print()
    print(analysis.format_table(model.level_bw, model.mix_penalty))
    if model.ridge_flops_per_byte:
        print(f"\nmeasured ridge point: {model.ridge_flops_per_byte:.1f} flop/B")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(**vars(ap.parse_args()))
