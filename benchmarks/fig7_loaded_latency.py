"""Fig 7 — loaded-latency surface (Mess-style bandwidth–latency curves).

The ``latency_chase`` probe measures per-step dependent-load latency; the
spec's ``load`` axis co-schedules bandwidth-generator streams next to it
(``bench/README.md``, "Loaded-latency surfaces").  Sweeping load at each
working-set size traces the memory system's bandwidth–latency curve: a flat
idle plateau, then latency taking off as the generators approach the
level's sustainable bandwidth.  The per-level knee fit
(``characterize.loaded.fit_loaded``) summarizes each curve into
(idle latency, knee load, knee generator GB/s) — the numbers a Mess-style
memory model feeds into a simulator.

This script is a thin declaration over
``repro.characterize.loaded.loaded_latency_sweep`` — the (sizes x loads)
grid is the only thing decided here.
"""
from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import emit
from repro.characterize.loaded import fit_loaded, loaded_latency_sweep

ART = Path(__file__).resolve().parents[1] / "artifacts"


def grid(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        return dict(sizes=(128 * 2**10,), loads=(0, 1, 2), reps=3)
    if quick:
        return dict(sizes=(128 * 2**10, 4 * 2**20), loads=(0, 1, 2, 4),
                    reps=3)
    return dict(sizes=(128 * 2**10, 4 * 2**20, 64 * 2**20),
                loads=(0, 1, 2, 4, 8), reps=5)


def main(quick: bool = False, smoke: bool = False, out: str | None = None,
         backend: str = "xla"):
    kw = grid(quick, smoke)
    res = loaded_latency_sweep(kw.pop("sizes"), kw.pop("loads"),
                               backend=backend, **kw)
    fit = fit_loaded(res)
    if fit:
        res.meta["loaded_latency"]["fit"] = fit

    for p in sorted(res.points, key=lambda p: (p.nbytes, p.load)):
        emit(f"fig7/{p.backend}/{p.nbytes}B/load{p.load}", p.mean_s * 1e6,
             f"{p.latency_ns:.2f}ns;{p.gen_gbps:.2f}GB/s-generated")
    for name, knee in ((fit or {}).get("levels") or {}).items():
        print(f"# {name}: idle {knee['idle_latency_ns']:.1f} ns, knee at "
              f"load={knee['knee_load']} ({knee['knee_gen_gbps']:.2f} GB/s), "
              f"max {knee['max_latency_ns']:.1f} ns")

    if out:
        res.to_json(out)
        print(f"# saved {len(res.points)} points "
              f"(schema v{res.schema_version}) -> {out}")
    elif not smoke:
        ART.mkdir(exist_ok=True)
        res.to_json(ART / "fig7_loaded_latency.json")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale grid — the CI smoke gate")
    ap.add_argument("--out", default=None,
                    help="write the schema-v5 result JSON here")
    ap.add_argument("--backend", default="xla", help="xla | pallas")
    main(**vars(ap.parse_args()))
