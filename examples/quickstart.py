"""Quickstart: characterize the machine, then train a small LM for 30 steps.

    PYTHONPATH=src python examples/quickstart.py

The measurement is one declarative BenchSpec executed by the repro.bench
Runner — the same API behind ``python -m repro.bench run`` (see
src/repro/bench/README.md for the knob -> paper mapping).
"""
import jax

from repro.bench import BenchSpec, Runner
from repro.configs import get_arch, reduced
from repro.core import analysis
from repro.core.machine_model import detect_host
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    # 1. membench: measure this machine's memory hierarchy (the paper's tool)
    print("== membench: hierarchy sweep (quick) ==")
    spec = BenchSpec(mixes=("load_sum", "fma_8"),
                     sizes=(32 * 2**10, 1 * 2**20, 16 * 2**20),
                     reps=4, warmup=2, target_bytes=3e7)
    res = Runner().run(spec)
    model = analysis.build_machine_model(res, detect_host())
    print(analysis.format_table(model.level_bw, model.mix_penalty))

    # 2. train a reduced granite for 30 steps on a named 3-axis mesh
    print("\n== train: granite-3-2b (reduced) 30 steps ==")
    cfg = reduced(get_arch("granite-3-2b"))
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    tcfg = TrainConfig(steps=30, ckpt_every=15, ckpt_dir="/tmp/quickstart_ckpt",
                       log_every=5,
                       opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=5,
                                             total_steps=30))
    trainer = Trainer(cfg, (8, 128), mesh, tcfg)
    _, _, hist = trainer.train(resume=False)
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {tcfg.steps} steps")


if __name__ == "__main__":
    main()
