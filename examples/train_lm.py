"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
checkpointing + resume (CPU-sized by default; --preset 100m for the full run).

    PYTHONPATH=src python examples/train_lm.py                  # ~20M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # ~100M params
"""
import argparse
from dataclasses import replace

from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    # ~20M params: CPU-friendly; a few hundred steps in minutes
    "20m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                d_ff=1024, vocab_size=8192, batch=8, seq=256),
    # ~100M params (the assignment's end-to-end scale)
    "100m": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
                 d_ff=2048, vocab_size=32768, batch=16, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    batch, seq = p.pop("batch"), p.pop("seq")
    cfg = replace(get_arch("granite-3-2b"), name=f"lm-{args.preset}", **p)

    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=max(50, args.steps // 4),
        ckpt_dir=args.ckpt_dir, log_every=10,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 20 + 1,
                              total_steps=args.steps))
    trainer = Trainer(cfg, (batch, seq), mesh, tcfg)
    _, _, hist = trainer.train()
    print(f"\n{cfg.name}: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if trainer.step_timer.slow_steps:
        print(f"straggler steps flagged: {trainer.step_timer.slow_steps}")


if __name__ == "__main__":
    main()
