"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    from repro.launch import serve
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--batch", str(args.batch), "--prompt-len", str(args.prompt_len),
                "--gen", str(args.gen)]
    serve.main()


if __name__ == "__main__":
    main()
