"""Standalone Arm-membench-style machine characterization (the paper's CLI).

Thin wrapper over ``repro.characterize``: adaptive fine-granularity sweep,
change-point topology detection (no sysfs/documentation input), fitted
machine model + report, plus the per-device straggler probe.  The heavy
lifting — and the ``--smoke``/``--full`` presets — live in
``python -m repro.bench characterize``; this example shows the library API.

    PYTHONPATH=src python examples/characterize_machine.py [--full]
"""
import argparse
from pathlib import Path

from repro.characterize import characterize, render_markdown
from repro.core.machine_model import detect_host
from repro.ft.stragglers import probe_devices


def main(full: bool = False):
    prior = detect_host()
    print(f"sysfs prior: {prior.name} ({len(prior.levels)} levels — "
          f"cross-checked below, not trusted)")

    if full:
        kw = dict(coarse_per_decade=4, hi=256 * 2**20, reps=10, warmup=2,
                  target_bytes=2e8, resolution=0.10)
        mixes = ("load_sum", "copy", "fma_1", "fma_2", "fma_8", "fma_32",
                 "fma_64")
    else:
        kw = dict(coarse_per_decade=3, reps=5, warmup=1, target_bytes=5e7,
                  resolution=0.25, max_rounds=4)
        mixes = ("load_sum", "copy", "fma_8", "fma_32")
    model, sweep = characterize(mixes=mixes, primary=mixes[0], prior=prior,
                                **kw)
    print(render_markdown(model, sweep))

    print("== per-device probe (straggler check) ==")
    for p in probe_devices(nbytes=1 * 2**20, passes=2, reps=3):
        flag = "  <-- STRAGGLER" if p.is_straggler else ""
        print(f"  {p.device}: {p.gbps:.2f} GB/s (z={p.z_score:+.2f}){flag}")

    out = Path("artifacts")
    out.mkdir(exist_ok=True)
    model.to_json(out / "fitted_machine_model.json")
    model.to_machine_model().to_json(out / "machine_model_host.json")
    sweep.result.to_json(out / "characterize_sweep.json")
    print(f"\nsaved: {out}/fitted_machine_model.json (+ legacy "
          f"machine_model_host.json, characterize_sweep.json)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
