"""Standalone Arm-membench-style machine characterization (the paper's CLI).

Runs the hierarchy sweep under multiple instruction mixes, attributes per-level
bandwidths, reports mix penalties + the measured ridge point, probes per-device
variance (straggler check), and saves a MachineModel JSON the framework's
autotuner and roofline analyzer consume.

    PYTHONPATH=src python examples/characterize_machine.py [--full]
"""
import argparse
import json
from pathlib import Path

from repro.bench import BenchSpec, Runner
from repro.core import analysis
from repro.core.buffers import sizes_logspace
from repro.core.machine_model import detect_host
from repro.ft.stragglers import probe_devices


def main(full: bool = False):
    host = detect_host()
    print(f"host: {host.name}")
    for lvl in host.levels:
        sz = f"{lvl.size_bytes}B" if lvl.size_bytes else "-"
        print(f"  {lvl.name}: {sz}")

    sizes = (sizes_logspace(16 * 2**10, 256 * 2**20, per_decade=6) if full
             else [32 * 2**10, 256 * 2**10, 2 * 2**20, 16 * 2**20, 64 * 2**20])
    mixes = (["load_sum", "copy", "fma_1", "fma_2", "fma_8", "fma_32", "fma_64"]
             if full else ["load_sum", "copy", "fma_8", "fma_32"])
    print(f"\nsweeping {len(sizes)} sizes x {len(mixes)} mixes ...")
    spec = BenchSpec(mixes=tuple(mixes), sizes=tuple(sizes),
                     reps=10 if full else 5, warmup=2,
                     target_bytes=2e8 if full else 5e7)
    res = Runner().run(spec)
    model = analysis.build_machine_model(res, host)

    print("\n== per-level bandwidth x instruction mix ==")
    print(analysis.format_table(model.level_bw, model.mix_penalty))
    if model.ridge_flops_per_byte:
        print(f"\nmeasured ridge point: {model.ridge_flops_per_byte:.1f} flop/B")
    print("\n== per-device probe (straggler check) ==")
    for p in probe_devices(nbytes=1 * 2**20, passes=2, reps=3):
        flag = "  <-- STRAGGLER" if p.is_straggler else ""
        print(f"  {p.device}: {p.gbps:.2f} GB/s (z={p.z_score:+.2f}){flag}")

    out = Path("artifacts")
    out.mkdir(exist_ok=True)
    model.to_json(out / "machine_model_host.json")
    res.to_json(out / "characterize_sweep.json")
    print(f"\nsaved: {out}/machine_model_host.json")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
