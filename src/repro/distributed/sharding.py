"""Logical-axis sharding: rules, divisibility-checked resolution, ShardCtx.

Models annotate every tensor dim with a *logical* axis name; this module maps
logical names to mesh axes.  A mapping is applied only when the dim size is
divisible by the mesh-axes product (shard_map regions require exact divisibility;
for jit-land tensors the same rule keeps layouts predictable) — otherwise the dim
falls back along the candidate chain (usually to replication), which is recorded
so the roofline report can call out replication waste (e.g. phi3's 40 heads on a
16-way model axis).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> ordered candidate mesh-axis tuples ("fsdp" expands to the data
# axes present in the mesh).  First candidate whose size divides the dim wins.
DEFAULT_RULES: dict[str, list[Optional[tuple[str, ...]]]] = {
    # weights
    "vocab": [("model",), None],
    "embed": [("fsdp",), None],
    "heads": [("model",), None],
    "kv_heads": [("model",), None],
    "head_dim": [None],
    "ffn": [("model",), None],
    "experts": [("model",), None],
    "kv_lora": [None],
    "inner": [("model",), None],
    "state": [None],
    "conv": [None],
    "layers": [None],
    "sites": [None],
    # activations
    "batch": [("dp",), None],          # dp expands to pod+data axes
    "seq": [None],
    "act_seq": [("model",), None],     # sequence parallelism: residual-stream seq
                                       # dim shards over model between blocks
    "act_heads": [("model",), None],
    # decode KV caches: batch takes the data axes first (if divisible), then the
    # sequence dim takes whatever is left — a 32k x 128 cache shards over the
    # full 256-chip pod (data x model), a 500k x 1 cache shards seq over data.
    "kv_seq": [("data",), ("model",), None],
}

FSDP_AXES = ("pod", "data")
DP_AXES = ("pod", "data")


def _expand(candidate: Optional[tuple[str, ...]], mesh: Mesh) -> Optional[tuple[str, ...]]:
    if candidate is None:
        return None
    out: list[str] = []
    for ax in candidate:
        if ax == "fsdp":
            out.extend(a for a in FSDP_AXES if a in mesh.axis_names)
        elif ax == "dp":
            out.extend(a for a in DP_AXES if a in mesh.axis_names)
        elif ax in mesh.axis_names:
            out.append(ax)
    return tuple(out) if out else None


@dataclass
class ShardCtx:
    """Carries the mesh + rules through model code; resolves logical -> physical."""
    mesh: Mesh
    rules: dict[str, list[Optional[tuple[str, ...]]]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))
    fallbacks: list[str] = field(default_factory=list)  # audit log of dropped axes

    # -- mesh helpers -------------------------------------------------------
    def axis_size(self, *names: str) -> int:
        return int(np.prod([self.mesh.shape[n] for n in names if n in self.mesh.axis_names] or [1]))

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in DP_AXES if a in self.mesh.axis_names)

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in FSDP_AXES if a in self.mesh.axis_names)

    @property
    def tp_axis(self) -> Optional[str]:
        return "model" if "model" in self.mesh.axis_names else None

    # -- resolution ---------------------------------------------------------
    def resolve_dim(self, logical: Optional[str], size: int,
                    used: Optional[set] = None) -> Optional[tuple[str, ...]]:
        """First candidate that is present, unused, and divides the dim."""
        if logical is None:
            return None
        used = used or set()
        for cand in self.rules.get(logical, [None]):
            axes = _expand(cand, self.mesh)
            if axes is None:
                return None
            if any(a in used for a in axes):
                continue  # axis already shards another dim — try next candidate
            total = int(np.prod([self.mesh.shape[a] for a in axes]))
            if total <= 1:
                continue
            if size % total == 0:
                return axes
            self.fallbacks.append(f"{logical}({size}) !% {axes}({total})")
        return None

    def spec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(axes), (shape, axes)
        used: set[str] = set()
        parts: list[Any] = []
        for size, logical in zip(shape, axes):
            r = self.resolve_dim(logical, size, used)
            if r is None:
                parts.append(None)
            else:
                used.update(r)
                parts.append(r if len(r) > 1 else r[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constrain(self, x, *axes: Optional[str]):
        """with_sharding_constraint by logical axes (len must match x.ndim)."""
        return jax.lax.with_sharding_constraint(x, self.sharding(x.shape, axes))

    # -- tree-level ---------------------------------------------------------
    # tree.map uses the first tree's structure; flatten_up_to stops at its leaf
    # boundary, so the axes tuples in the second tree arrive whole.
    def tree_shardings(self, abstract_tree, axes_tree):
        return jax.tree.map(lambda sds, ax: self.sharding(sds.shape, ax),
                            abstract_tree, axes_tree)

    def tree_abstract(self, abstract_tree, axes_tree):
        """Attach shardings to a ShapeDtypeStruct tree (dry-run inputs)."""
        def one(sds, ax):
            return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                        sharding=self.sharding(sds.shape, ax))
        return jax.tree.map(one, abstract_tree, axes_tree)


def make_smoke_ctx() -> ShardCtx:
    """1-device mesh with the production axis names (CPU tests).  On jax
    0.4.x the AxisType/axis_types surface comes from repro.compat."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return ShardCtx(mesh)
