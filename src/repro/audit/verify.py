"""Static accounting verifier — declared work vs compiled-IR observation.

Every mix in the registry *declares* its traffic (``MixDef.bytes_per_pass`` /
``flops_per_pass``: the paper-logical accounting every GB/s and flops/s
number in the repo is normalized by).  The verifier cross-checks those
declarations against what the compiled HLO actually does, per pass-loop
iteration, using the demand-weighted extractor (``repro.istream.extract``).

Three layers of checking per case:

* **formula lint** (``lint_mix``) — the declared per-element numbers must be
  internally consistent with the mix's structural parameters (``rw=(R, W)``
  must match ``reads_per_elem``/``writes_per_elem``; ``fma_depth=k`` must
  match ``flops_per_elem == 2k``; and so on).  Pure registry math, no HLO.
* **compiled-traffic check** — observed loads/stores/arith per pass vs
  ``expected_counts``: the declared numbers *mapped through the known,
  calibrated compiler behaviors* (see ``expected_counts`` and
  ``audit/README.md`` for the per-(family, backend) derivations).  The
  tolerance covers scalar loop scaffolding only — a wrong formula or a
  transformed timed region lands far outside it.
* **liveness checks** — the pass loop must exist with the right trip count,
  and the timed body must move a working set's worth of data (explicit
  detection of hoisted / dead-code-eliminated timed work: the failure mode
  that silently turns a bandwidth benchmark into an empty-loop timer).

Cases with no stable expectation (documented caveats, e.g. the interpret-
mode ``load_only`` DCE) are *waived*: reported, never failed.

Entry points: ``audit_registry`` (live: lowers every registered mix ×
backend × knob combination), ``audit_hlo`` / ``audit_goldens`` (deviceless:
run the same checks over compiled-HLO text fixtures).
"""
from __future__ import annotations

import dataclasses
import json
import random as _random
from dataclasses import dataclass, field
from pathlib import Path

from repro.bench.mixes import (MAX_RW, MixDef, get_mix, interleavable,
                               mix_names, rw_name)
from repro.bench.spec import BenchSpec, BenchSpecError

# exit code contract shared with the CLI (``python -m repro.bench audit``)
EXIT_OK = 0
EXIT_VIOLATION = 2

# lanes of the canonical audit shape (the MXU/VPU minor dim everywhere in
# this repo); the mxu weight panel is LANES x LANES
LANES = 128

# tolerance for the compiled-traffic checks: scalar scaffolding (the
# perturbation chain, loop counters, eps adds) contributes a handful of
# element-ops per unrolled sweep — RTOL covers systematic slack, the atol
# term covers the per-sweep scalar constant.
RTOL = 0.03
ATOL_ELEMS_PER_SWEEP = 64.0

# a timed region whose observed traffic falls below this fraction of one
# working-set read is considered eliminated, not merely mis-accounted
DCE_FRACTION = 0.5

# calibrated scalar bookkeeping of the chase walk's fori_loop, per chain
# step (compare / counter increment / index arithmetic — measured identical
# on xla and interpret-mode pallas, and unroll-invariant on xla)
CHASE_LOOP_ARITH_PER_STEP = 5.0


# --------------------------------------------------------------------------
# expected compiled traffic
# --------------------------------------------------------------------------

def waiver_reason(mix: MixDef, backend: str,
                  knobs: dict | None = None) -> str | None:
    """Why a case carries no stable compiled-traffic expectation (it is
    *waived*: observed counts reported, never failed) — or None when the
    case is fully checkable.  Every waiver names a calibrated, documented
    behavior; the list doubles as the repo's known-measurement-caveats
    registry (see audit/README.md).

    The ``unroll`` knob carries NO waiver: since the rotating-carry fix
    (every unrolled sweep's outputs are live loop state on both backends),
    carried-mix unroll is fully checkable — ``expected_counts`` covers the
    unroll axis and the auditor enforces per-pass traffic ≈ u× one sweep.

    * chunked interleave variants (``k_*_istream`` / chunked kernel
      bodies) restructure traffic per chunk (partial materialization,
      chunk-level narrowing) with no closed form across (mix, chunks).
    * blocked/strided xla reductions (``load_sum`` off the default
      tiling) materialize per-block/per-stream partials.
    * pallas interpret mode with more than one grid block scales the
      emulation's buffer traffic with the block count.
    * interpret-mode ``load_only`` is DCE'd outright (documented in
      istream/README.md): a dead load with no consumer.
    """
    from repro.bench.mixes import _BACKEND_ALIASES
    b = _BACKEND_ALIASES.get(backend, backend)
    knobs = knobs or {}
    unroll = knobs.get("unroll") or 1
    interleave = knobs.get("interleave") or 1
    streams = knobs.get("streams") or 1
    multi_knob = (streams > 1 or knobs.get("block_rows") is not None)
    del unroll   # checkable on every mix/backend since the rotating-carry fix
    if mix.name == "load_only":
        return "interpret-mode DCE of the dead load (documented caveat)"
    if interleave > 1:
        return ("chunked interleave variant restructures per-chunk traffic "
                "(no closed form)")
    if b == "pallas" and multi_knob:
        return ("interpret-mode grid emulation scales traffic with block "
                "count (multi-block tiling)")
    if b == "xla" and mix.name == "load_sum" and multi_knob:
        return ("blocked/strided reduction materializes per-partial sums "
                "off the default tiling")
    return None


def expected_counts(mix: MixDef, backend: str, n: float,
                    knobs: dict | None = None) -> dict | None:
    """Per-pass loads/stores/arith (in elements) the *compiled* HLO is
    expected to show for ``mix`` on ``backend``, derived from the mix's
    DECLARED accounting numbers plus the calibrated compiler behaviors.

    Deriving from the declared numbers (``reads_per_elem`` etc.), not the
    structural parameters, is what makes this a verifier: corrupt a
    declaration and the expectation moves away from the (unchanged)
    compiled code, so the audit fails naming the case.

    Calibrated behaviors encoded here (measured on XLA:CPU, see
    ``audit/README.md`` for the probes):

    * ``fma`` (both backends): XLA never fuses a computed producer into a
      full-array reduce, so the chain materializes once per pass — one
      extra write + re-read of n elements, and the final sum adds n flops.
    * ``copy`` on xla: the scale multiply that defeats copy-elision
      executes per pass (n flops of scaffolding over the declared 0).
    * ``rw_RtoW`` on xla: the combine is re-fused per write stream, so
      loads and arith scale with W (loads = R*W*n, arith = 2*R*W*n — the
      declared 2(R-1)n plus the per-output store-side add, duplicated).
    * ``mxu``: the weight panel (LANES^2 elements) streams per pass next
      to the declared n-element read; the product materializes (n stores).
    * ``latency_chase``: the dependent-chain walk issues the declared
      R loads per element (dependent loads are unhoistable — the walk
      survives optimization intact on both backends) plus
      ``CHASE_LOOP_ARITH_PER_STEP`` scalar bookkeeping arith per step.
      The loaded composite adds ``load * GEN_SWEEPS_PER_PASS`` load_sum
      generator sweeps per pass (their declared n loads + n arith each)
      plus small calibrated scaffolding residuals that scale with the
      buffer's row count (the per-sweep perturbation chain; see the chase
      branch below and audit/README.md).  Pallas interpret mode
      materializes the carried perm buffer at unrolled-sweep boundaries:
      a (load+store) mirror of ``max(u-1, 2) * n`` elems per TRIP for
      u > 1 (calibrated at u = 2, 4, 8).
    * pallas interpret mode emulates the kernel's explicit output buffers:
      R=1 write-bearing mixes double (copy / rw_1toW read AND write both
      the input image and the W outputs), multi-read mixes share the
      emulated input (loads = (R+W-1)n for R,W >= 2).
    * unroll (u sweeps per loop trip, rotating-carry): xla traffic is
      u x one sweep per trip, i.e. per-pass counts are unroll-invariant.
      In pallas interpret mode the per-TRIP emulation overheads amortize
      across the u sweeps: the R=1/W=1 input mirror materializes once per
      trip (loads = stores = (W + 1/u)n per pass), and mxu's emulated
      weight-panel store + grid bookkeeping likewise divide by u
      (stores = n + LANES^2/u, arith = (f + 4/u)n).  rw mixes with W >= 2
      or R >= 2 and triad are unroll-flat.

    Returns None when no stable expectation exists (documented caveat —
    the case is *waived*, reported but never failed).
    """
    from repro.bench.mixes import _BACKEND_ALIASES
    b = _BACKEND_ALIASES.get(backend, backend)
    if b not in ("xla", "pallas"):
        return None
    if waiver_reason(mix, backend, knobs) is not None:
        return None
    u = max((knobs or {}).get("unroll") or 1, 1)
    R, W, f = mix.reads_per_elem, mix.writes_per_elem, mix.flops_per_elem
    name = mix.name
    if mix.chase:
        # Serial walk: R dependent loads per element + calibrated fori_loop
        # bookkeeping.  Loaded composite: G*L generator sweeps (declared
        # load_sum traffic) + residuals measured exactly at rows in
        # {32, 64, 128}: L*(2*rows+16) loads, L*(2*rows+32) stores,
        # L*(2*rows+80) arith (per-sweep perturbation-chain scaffolding;
        # rows = n / LANES on the canonical audit shapes).
        from repro.bench.mixes import GEN_SWEEPS_PER_PASS
        load = (knobs or {}).get("load") or 0
        rows = n / LANES
        gl = load * GEN_SWEEPS_PER_PASS
        loads = (R + gl) * n + load * (2 * rows + 16)
        stores = load * (2 * rows + 32)
        arith = (CHASE_LOOP_ARITH_PER_STEP + gl) * n + load * (2 * rows + 80)
        if b == "pallas" and u > 1:
            # interpret-mode carry materialization at sweep boundaries
            mirror = max(u - 1, 2) * n / u
            loads += mirror
            stores += mirror
        return {"loads": loads, "stores": stores, "arith": arith}
    if name.startswith("fma_"):
        return {"loads": (R + 1) * n, "stores": n, "arith": (f + 1) * n}
    if name == "load_sum":
        return {"loads": R * n, "stores": 0.0, "arith": f * n}
    if name == "mxu":
        loads = R * n + LANES * LANES
        if b == "xla":
            return {"loads": loads, "stores": n, "arith": f * n}
        # interpret emulation mirrors the input+weight streams on the store
        # side once per TRIP (amortized over the u sweeps); the emulated
        # grid adds ~4n/u bookkeeping arith per pass
        return {"loads": loads, "stores": n + LANES * LANES / u,
                "arith": (f + 4 / u) * n}
    if name == "triad":
        return {"loads": R * n, "stores": W * n, "arith": f * n}
    if name == "copy" or mix.rw is not None:
        if b == "xla":
            if name == "copy":
                return {"loads": R * n, "stores": W * n, "arith": (f + 1) * n}
            return {"loads": R * W * n, "stores": W * n, "arith": 2 * R * W * n}
        # pallas interpret
        if R <= 1:
            if W <= 1:
                # the emulated input mirror materializes once per trip
                mirror = (W + 1 / u) * n
                return {"loads": mirror, "stores": mirror, "arith": f * n}
            return {"loads": (W + 1) * n, "stores": (W + 1) * n, "arith": f * n}
        if W <= 1:
            return {"loads": R * n, "stores": n, "arith": f * n}
        return {"loads": (R + W - 1) * n, "stores": W * n, "arith": f * n}
    return None


def lint_mix(mix: MixDef) -> list[tuple[str, bool, str]]:
    """Registry-internal consistency: declared per-element numbers vs the
    mix's structural parameters.  Returns (check, ok, detail) triples."""
    out = []
    if mix.rw is not None:
        R, W = mix.rw
        out.append(("formula:reads", mix.reads_per_elem == R,
                    f"reads_per_elem={mix.reads_per_elem} vs rw R={R}"))
        out.append(("formula:writes", mix.writes_per_elem == W,
                    f"writes_per_elem={mix.writes_per_elem} vs rw W={W}"))
        out.append(("formula:flops", mix.flops_per_elem == 2 * (R - 1),
                    f"flops_per_elem={mix.flops_per_elem} vs 2(R-1)={2*(R-1)}"))
    if mix.name.startswith("fma_"):
        k = mix.fma_depth
        out.append(("formula:flops", mix.flops_per_elem == 2 * k,
                    f"flops_per_elem={mix.flops_per_elem} vs 2k={2 * k}"))
    if mix.name == "triad":
        out.append(("formula:triad", (mix.reads_per_elem, mix.writes_per_elem,
                                      mix.flops_per_elem) == (2.0, 1.0, 2.0),
                    f"triad declares (R,W,f)=({mix.reads_per_elem},"
                    f"{mix.writes_per_elem},{mix.flops_per_elem}) != (2,1,2)"))
    if mix.chase:
        out.append(("formula:chase", (mix.reads_per_elem, mix.writes_per_elem,
                                      mix.flops_per_elem) == (1.0, 0.0, 0.0),
                    f"chase declares (R,W,f)=({mix.reads_per_elem},"
                    f"{mix.writes_per_elem},{mix.flops_per_elem}) != (1,0,0) "
                    "(one dependent load per step, nothing else)"))
    return out


# --------------------------------------------------------------------------
# per-case audit
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Check:
    name: str
    ok: bool
    detail: str


@dataclass
class CaseAudit:
    """Declared vs observed accounting for ONE compiled case."""
    mix: str
    backend: str
    shape: tuple
    dtype: str
    passes: int
    knobs: dict                    # streams / block_rows / unroll / interleave
    declared: dict                 # registry accounting (per pass)
    expected: dict | None          # compiled-traffic expectation (per pass)
    observed: dict                 # extracted counts (per pass)
    checks: list[Check] = field(default_factory=list)
    waived: bool = False           # no expectation: reported, never failed
    waived_reason: str | None = None

    @property
    def ok(self) -> bool:
        return self.waived or all(c.ok for c in self.checks)

    @property
    def failures(self) -> list[Check]:
        return [] if self.waived else [c for c in self.checks if not c.ok]

    def where(self) -> str:
        """mix/backend/knob triple naming the case in violation output.
        A knob at its no-op value is elided — 0 for the count-like ``load``
        axis, 1 for the multiplier-like knobs (unroll/streams/interleave)."""
        knobs = ",".join(f"{k}={v}" for k, v in sorted(self.knobs.items())
                         if v is not None
                         and v != (0 if k == "load" else 1))
        return f"{self.backend}/{self.mix}" + (f"[{knobs}]" if knobs else "")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(d["shape"])
        d["ok"] = self.ok
        return d


def _close(obs: float, exp: float, n: float, unroll: int) -> bool:
    atol = ATOL_ELEMS_PER_SWEEP * max(unroll, 1)
    return abs(obs - exp) <= atol + RTOL * max(exp, 0.01 * n)


def audit_counts(mix: MixDef, backend: str, shape, dtype: str, passes: int,
                 per_iter: dict, loop, trips: int, unroll: int = 1,
                 knobs: dict | None = None) -> CaseAudit:
    """The pure core: extracted per-iteration counts -> CaseAudit.

    Shared by the live path (``audit_case``, via ``istream.analyze``) and
    the deviceless path (``audit_hlo``, over golden HLO text)."""
    import numpy as np
    n = float(np.prod(shape)) if shape else 1.0
    itemsize = np.dtype(dtype).itemsize
    unroll = max(unroll, 1)
    knobs = dict(knobs or {})
    knobs.setdefault("unroll", unroll)

    # per-iteration -> per-pass: one loop trip covers ``unroll`` sweeps
    obs = {k: per_iter.get(k, 0.0) / unroll
           for k in ("loads", "stores", "arith", "move")}
    obs["bytes"] = (obs["loads"] + obs["stores"]) * itemsize
    declared = {"bytes": mix.bytes_per_pass(int(n) * itemsize),
                "flops": mix.flops_per_pass(int(n))}
    exp = expected_counts(mix, backend, n, knobs=knobs)

    checks = [Check(name, ok, detail) for name, ok, detail in lint_mix(mix)]
    expected_trips = max(passes // unroll, 1)
    if expected_trips > 1:
        checks.append(Check(
            "loop", loop is not None,
            f"pass loop {'found' if loop else 'MISSING'} "
            f"(expected {expected_trips} trips)"))
        if loop is not None:
            checks.append(Check(
                "trips", trips == expected_trips,
                f"trip count {trips} vs passes/unroll={expected_trips}"))

    audit = CaseAudit(mix=mix.name, backend=backend, shape=tuple(shape),
                      dtype=str(dtype), passes=passes, knobs=knobs,
                      declared=declared, expected=exp, observed=obs,
                      checks=checks, waived=exp is None,
                      waived_reason=(waiver_reason(mix, backend, knobs)
                                     or "no expectation for this backend")
                      if exp is None else None)
    if exp is None:
        from repro.obs import metrics
        metrics.REGISTRY.inc("audit_waivers")
        return audit

    # liveness first: an eliminated timed region fails loudly by name, not
    # as a numeric near-miss
    exp_traffic = exp["loads"] + exp["stores"]
    if exp_traffic > 0 and (obs["loads"] + obs["stores"]) \
            < DCE_FRACTION * min(n, exp_traffic):
        checks.append(Check(
            "dce", False,
            f"timed work eliminated: observed "
            f"{obs['loads'] + obs['stores']:.0f} traffic elems/pass vs "
            f"expected {exp_traffic:.0f} (hoisted or dead-code-eliminated)"))
        return audit
    for key in ("loads", "stores", "arith"):
        checks.append(Check(
            key, _close(obs[key], exp[key], n, unroll),
            f"observed {obs[key]:.0f} vs expected {exp[key]:.0f} "
            f"elems/pass (declared "
            f"{declared['bytes' if key != 'arith' else 'flops']:.0f} "
            f"{'bytes' if key != 'arith' else 'flops'})"))
    return audit


def audit_case(spec: BenchSpec, mix_name: str, shape, dtype, passes: int,
               runner=None, cache=None) -> CaseAudit:
    """Live audit of one case: lower via the Runner's coordinates (no
    working set materialized), extract, cross-check."""
    from repro.istream.analyze import analyze_case
    prof = analyze_case(spec, mix_name, shape, dtype, passes,
                        runner=runner, cache=cache)
    return audit_counts(
        get_mix(mix_name), spec.backend, shape, str(prof.dtype), passes,
        prof.per_iter, prof.loop, prof.trips, unroll=spec.unroll,
        knobs={"streams": spec.streams, "block_rows": spec.block_rows,
               "unroll": spec.unroll, "interleave": spec.interleave,
               "load": spec.load})


def audit_hlo(hlo_text: str, mix_name: str, backend: str, shape,
              dtype: str = "float32", passes: int = 4, unroll: int = 1,
              knobs: dict | None = None) -> CaseAudit:
    """Deviceless audit: same checks, over compiled-HLO text (goldens)."""
    from repro.istream.extract import extract_profile
    raw = extract_profile(hlo_text,
                          expected_trips=max(passes // max(unroll, 1), 1))
    return audit_counts(get_mix(mix_name), backend, shape, dtype, passes,
                        raw["per_iter"], raw["loop"], raw["trips"],
                        unroll=unroll, knobs=knobs)


# --------------------------------------------------------------------------
# registry-wide audit
# --------------------------------------------------------------------------

@dataclass
class AuditReport:
    cases: list[CaseAudit] = field(default_factory=list)
    skipped: list[dict] = field(default_factory=list)   # knob-gated combos
    meta: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.cases)

    @property
    def violations(self) -> list[CaseAudit]:
        return [c for c in self.cases if not c.ok]

    @property
    def waived(self) -> list[CaseAudit]:
        return [c for c in self.cases if c.waived]

    def table(self) -> str:
        rows = [f"{'case':28s} {'decl B/pass':>12s} {'obs B/pass':>12s} "
                f"{'decl flop':>10s} {'obs flop':>10s}  status"]
        for c in self.cases:
            status = ("waived" if c.waived else
                      "ok" if c.ok else
                      "FAIL " + ",".join(f.name for f in c.failures))

            def cell(d, key, width):
                return f"{d[key]:{width}.0f}" if key in d else f"{'-':>{width}s}"
            rows.append(
                f"{c.where():28s} {cell(c.declared, 'bytes', 12)} "
                f"{cell(c.observed, 'bytes', 12)} "
                f"{cell(c.declared, 'flops', 10)} "
                f"{cell(c.observed, 'arith', 10)}  {status}")
        for s in self.skipped:
            rows.append(f"{s['case']:28s} {'-':>12s} {'-':>12s} {'-':>10s} "
                        f"{'-':>10s}  skipped ({s['reason']})")
        counts = (f"# {len(self.cases)} cases: "
                  f"{sum(c.ok and not c.waived for c in self.cases)} ok, "
                  f"{len(self.waived)} waived, "
                  f"{len(self.violations)} violations, "
                  f"{len(self.skipped)} skipped")
        return "\n".join(rows + [counts])

    def to_dict(self) -> dict:
        return {"schema": "repro.audit/v1", "ok": self.ok,
                "summary": {
                    "ok": sum(c.ok and not c.waived for c in self.cases),
                    "waived": len(self.waived),
                    "violations": len(self.violations),
                    "skipped": len(self.skipped)},
                "meta": self.meta,
                "cases": [c.to_dict() for c in self.cases],
                "skipped": self.skipped}

    def to_json(self, path=None) -> str:
        s = json.dumps(self.to_dict(), indent=2)
        if path is not None:
            Path(path).write_text(s)
        return s

    def exit_code(self) -> int:
        return EXIT_OK if self.ok else EXIT_VIOLATION


def random_rw_pairs(k: int, seed: int = 0,
                    max_side: int = MAX_RW) -> list[str]:
    """Deterministic pseudo-random rw_RtoW sample (property-test surface)."""
    rng = _random.Random(seed)
    out = []
    for _ in range(k):
        out.append(rw_name(rng.randint(1, max_side), rng.randint(1, max_side)))
    return sorted(set(out))


def default_knob_grid(smoke: bool = False) -> list[dict]:
    """One-factor-at-a-time knob coverage: the base case plus each knob
    exercised alone (a full cross product would compile hundreds of cases
    for no additional formula coverage — each knob's traffic effect is
    independent by construction).  Smoke keeps the base case plus the
    unroll axis at {2, 4} — the CI fast-fail gate that pins the
    rotating-carry fix (carried-mix unroll is enforced, not waived) — plus
    the loaded-latency composite at load=1 (chase mixes only; the guard in
    ``audit_registry`` skips the load knob for everything else)."""
    if smoke:
        return [{}, {"unroll": 2}, {"unroll": 4}, {"load": 1}]
    # streams rides with a small block so the pallas tiling yields enough
    # blocks to split on the compact audit shape; block_rows=32 makes the
    # tiling axis non-trivial (2+ blocks) on the default 64-row shape
    return [{}, {"streams": 2, "block_rows": 16}, {"unroll": 2},
            {"interleave": 2}, {"block_rows": 32}, {"load": 1}]


SMOKE_MIXES = ("copy", "triad", "rw_2to1", "latency_chase")


def audit_registry(backends=("xla", "pallas"), mixes=None, shape=(64, 128),
                   dtype: str = "float32", passes: int = 4,
                   knob_grid: list[dict] | None = None, rw_pairs: int = 0,
                   seed: int = 0, smoke: bool = False,
                   cache=None) -> AuditReport:
    """Audit every registered mix on every requested backend across the
    knob grid.  ``smoke=True``: three representative mixes, base knobs only
    (the CI fast-fail gate).  ``rw_pairs=k``: additionally audits k random
    rw_RtoW family members (the open-ended-family surface)."""
    import numpy as np
    from repro.istream.analyze import ProfileCache
    cache = cache if cache is not None else ProfileCache()
    knob_grid = knob_grid if knob_grid is not None else \
        default_knob_grid(smoke)
    n = int(np.prod(shape))
    nbytes = n * np.dtype(dtype).itemsize
    report = AuditReport(meta={"shape": list(shape), "dtype": dtype,
                               "passes": passes, "smoke": smoke,
                               "knob_grid": knob_grid, "backends": list(backends)})
    for backend in backends:
        names = list(mixes) if mixes is not None else \
            (list(SMOKE_MIXES) if smoke else mix_names(backend))
        if rw_pairs:
            names += [p for p in random_rw_pairs(rw_pairs, seed)
                      if p not in names]
        for name in names:
            mix = get_mix(name)
            if not mix.supports(backend):
                continue
            for knobs in knob_grid:
                if knobs.get("interleave", 1) > 1 and not interleavable(mix):
                    continue
                # the load axis only exists on chase mixes (the spec gates
                # it); skip silently rather than emit a skipped row per mix
                if (knobs.get("load") or 0) > 0 and not mix.chase:
                    continue
                case_id = f"{backend}/{name}" + \
                    (f"[{','.join(f'{k}={v}' for k, v in sorted(knobs.items()))}]"
                     if knobs else "")
                u = max(knobs.get("unroll", 1) or 1, 1)
                p = passes
                if p % u:
                    p = passes * u
                # fewer than 2 trips lets XLA fully unroll the pass loop
                # (no loop found -> whole-module counts -> spurious noise)
                p = max(p, 2 * u)
                try:
                    spec = BenchSpec(mixes=(name,), sizes=(nbytes,),
                                     backend=backend, dtype=dtype, passes=p,
                                     reps=2, warmup=0, **knobs)
                except BenchSpecError as e:
                    report.skipped.append({"case": case_id, "reason": str(e)})
                    continue
                try:
                    report.cases.append(
                        audit_case(spec, name, shape, dtype, p, cache=cache))
                except BenchSpecError as e:   # knob gated at make_case time
                    report.skipped.append({"case": case_id, "reason": str(e)})
                except Exception as e:   # lowering failure IS an audit finding
                    report.cases.append(CaseAudit(
                        mix=name, backend=backend, shape=tuple(shape),
                        dtype=dtype, passes=p, knobs=dict(knobs),
                        declared={}, expected=None, observed={},
                        checks=[Check("lower", False,
                                      f"{type(e).__name__}: {e}")],
                        waived=False))
    return report


# --------------------------------------------------------------------------
# golden fixtures (deviceless CI path)
# --------------------------------------------------------------------------

# (mix, backends, unroll[, knobs]): the unroll>1 rows pin the
# rotating-carry lowering for every carried-mix family head — regenerating
# them after a kernel edit that reintroduces dead interior sweeps flips the
# deviceless audit red with no device in the loop.  The chase rows pin the
# latency probe's dependent-load walk (unloaded) and the loaded composite
# (the optional trailing knobs dict, e.g. {"load": 1}).
GOLDEN_SET = (("load_sum", ("xla", "pallas"), 1),
              ("copy", ("xla", "pallas"), 1),
              ("triad", ("xla", "pallas"), 1),
              ("rw_2to1", ("xla", "pallas"), 1),
              ("fma_8", ("xla", "pallas"), 1),
              ("copy", ("xla", "pallas"), 2),
              ("triad", ("xla", "pallas"), 2),
              ("rw_2to1", ("xla", "pallas"), 2),
              ("copy", ("xla", "pallas"), 4),
              ("triad", ("xla", "pallas"), 4),
              ("rw_2to1", ("xla", "pallas"), 4),
              ("latency_chase", ("xla", "pallas"), 1),
              ("latency_chase", ("xla", "pallas"), 1, {"load": 1}))


def _golden_passes(passes: int, unroll: int) -> int:
    """Pass count for a golden case: a multiple of unroll with >= 2 trips
    (1-trip loops get fully unrolled by XLA and lose the pass loop)."""
    p = passes if passes % unroll == 0 else passes * unroll
    return max(p, 2 * unroll)


def write_goldens(out_dir, shape=(64, 128), dtype: str = "float32",
                  passes: int = 4) -> dict:
    """Lower the golden case set and write compiled-HLO text fixtures plus
    a manifest.json (the deviceless audit's input).  Regenerate with
    ``python -m repro.bench audit --write-goldens tests/data/hlo``."""
    import numpy as np
    from repro.istream.analyze import lower_case
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    n = int(np.prod(shape))
    nbytes = n * np.dtype(dtype).itemsize
    manifest = {"shape": list(shape), "dtype": dtype, "passes": passes,
                "unroll": 1, "cases": []}
    for entry in GOLDEN_SET:
        name, backends, unroll = entry[:3]
        extra = dict(entry[3]) if len(entry) > 3 else {}
        p = _golden_passes(passes, unroll)
        for backend in backends:
            spec = BenchSpec(mixes=(name,), sizes=(nbytes,), backend=backend,
                             dtype=dtype, passes=p, reps=2, warmup=0,
                             unroll=unroll, **extra)
            hlo = lower_case(spec, name, shape, dtype, p)
            fname = f"{backend}__{name}__{'x'.join(map(str, shape))}" \
                    f"__{dtype}__p{p}" \
                    f"{f'__u{unroll}' if unroll > 1 else ''}" \
                    f"{''.join(f'__{k}{v}' for k, v in sorted(extra.items()))}" \
                    ".txt"
            (out_dir / fname).write_text(hlo)
            case = {"file": fname, "mix": name, "backend": backend}
            if unroll > 1:
                case["unroll"] = unroll
                case["passes"] = p
            if extra:
                case["knobs"] = extra
            manifest["cases"].append(case)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def audit_goldens(golden_dir) -> AuditReport:
    """Deviceless audit over the fixture directory's manifest."""
    golden_dir = Path(golden_dir)
    manifest = json.loads((golden_dir / "manifest.json").read_text())
    shape = tuple(manifest["shape"])
    report = AuditReport(meta={"goldens": str(golden_dir),
                               "shape": list(shape),
                               "dtype": manifest["dtype"],
                               "passes": manifest["passes"]})
    for case in manifest["cases"]:
        hlo = (golden_dir / case["file"]).read_text()
        unroll = case.get("unroll", manifest.get("unroll", 1))
        knobs = dict(case.get("knobs") or {})
        if unroll > 1:
            knobs["unroll"] = unroll
        report.cases.append(audit_hlo(
            hlo, case["mix"], case["backend"], shape,
            dtype=manifest["dtype"],
            passes=case.get("passes", manifest["passes"]),
            unroll=unroll,
            knobs=knobs or None))
    return report
