"""ECM-style analytic predictor over extracted instruction profiles.

The Execution-Cache-Memory model (Hager et al.; the analytic companion of
the paper's measured curves) decomposes a streaming kernel's per-pass time
into an *in-core issue term* and *per-level transfer terms*:

    t_core = issue element-ops / fitted issue rate
    t_data = sum over hierarchy levels the data streams through of
             (compiled traffic bytes / that level's measured bandwidth)
    t_pred = max(t_core, t_data)        # full-overlap assumption

Both inputs come from THIS repo's measurement subsystems: the issue rate and
per-level bandwidths from a ``characterize.FittedMachineModel`` (schema v2),
the issue element-ops and compiled traffic from the demand-weighted HLO
extractor (``istream.extract``) — so a prediction needs one compile and NO
timing.  The full-overlap max is the optimistic ECM variant; the transfer
terms themselves serialize (classic non-overlapping inter-level transfers),
which is the right pessimism for load/store streams that share one port.

Two consumers:

* ``validate_ecm`` — predicted vs measured across a finished sweep (the
  fig3 block-shape study reports this table; relative error is the model's
  honesty metric).
* ``predict_block_rows`` / ``ecm_filter_rows`` — closed-form block-shape
  ranking for ``core.autotune``: candidates whose block tile spills the
  innermost level pay outer-level transfer time, candidates with tiny
  blocks pay per-block issue overhead, and the autotuner times only the
  top-k survivors instead of the whole ladder.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


# per-block issue overhead (element-op equivalents) charged by the analytic
# block-shape model: grid bookkeeping, block address arithmetic, loop
# control.  One VPU-tile's worth per block is the calibrated order of
# magnitude; the *ranking* (not the absolute time) is what the prefilter
# consumes, and the ranking is insensitive to 2x either way.
BLOCK_OVERHEAD_ELEMS = 1024.0


@dataclass
class EcmPrediction:
    """Analytic per-pass decomposition for one case."""
    mix: str
    backend: str
    nbytes: int
    t_core_s: float
    t_data_s: float
    level_times: dict = field(default_factory=dict)   # level -> seconds/pass
    declared_bytes: float = 0.0

    @property
    def t_pred_s(self) -> float:
        return max(self.t_core_s, self.t_data_s)

    @property
    def bound(self) -> str:
        return "core" if self.t_core_s >= self.t_data_s else "data"

    @property
    def gbps(self) -> float:
        """Effective declared-bytes throughput (comparable to
        BenchPoint.gbps, which normalizes by the same declared bytes)."""
        t = self.t_pred_s
        return self.declared_bytes / t / 1e9 if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {"mix": self.mix, "backend": self.backend,
                "nbytes": self.nbytes, "t_core_s": self.t_core_s,
                "t_data_s": self.t_data_s, "t_pred_s": self.t_pred_s,
                "level_times": self.level_times, "bound": self.bound,
                "gbps": self.gbps}


def _issue_rate(model) -> float | None:
    issue = getattr(model, "issue", None) or {}
    return issue.get("rate_elems_per_s")


def ecm_predict(profile, model, mix=None) -> EcmPrediction:
    """Analytic per-pass time for one extracted ``InstructionProfile``
    against a ``FittedMachineModel`` — no timing, one compile."""
    from repro.bench.mixes import get_mix
    m = get_mix(mix or profile.mix)
    unroll = max(profile.unroll, 1)
    itemsize = profile.nbytes // max(
        int(math.prod(profile.shape)) if profile.shape else 1, 1)
    obs_bytes = (profile.per_iter["loads"] + profile.per_iter["stores"]) \
        / unroll * max(itemsize, 1)
    issue_per_pass = profile.issue_elems_per_iter / unroll

    rate = _issue_rate(model)
    t_core = issue_per_pass / rate if rate else 0.0
    level_times = {}
    for lvl in model.level_path(profile.nbytes):
        bw = model.bandwidth_for(lvl, m.name)
        if bw:
            level_times[lvl.name] = obs_bytes / bw
    t_data = sum(level_times.values())
    return EcmPrediction(mix=m.name, backend=profile.backend,
                         nbytes=profile.nbytes, t_core_s=t_core,
                         t_data_s=t_data, level_times=level_times,
                         declared_bytes=m.bytes_per_pass(profile.nbytes))


def validate_ecm(pairs, model) -> dict:
    """Predicted vs measured over (BenchPoint, InstructionProfile) pairs.

    Per point: predicted call time = t_pred/pass x passes; relative error
    against the measured mean.  Returns rows + the summary stats the fig3
    harness prints (median/max absolute relative error)."""
    rows = []
    for point, prof in pairs:
        if prof is None or point.mean_s <= 0:
            continue
        pred = ecm_predict(prof, model, mix=point.mix)
        pred_s = pred.t_pred_s * max(point.passes, 1)
        rel = (pred_s - point.mean_s) / point.mean_s
        rows.append({"mix": point.mix, "backend": point.backend,
                     "nbytes": point.nbytes,
                     "knobs": {"block_rows": getattr(point, "block_rows", None),
                               "unroll": point.unroll},
                     "measured_s": point.mean_s, "predicted_s": pred_s,
                     "rel_err": rel, "bound": pred.bound,
                     "measured_gbps": point.gbps, "predicted_gbps": pred.gbps})
    errs = sorted(abs(r["rel_err"]) for r in rows)
    med = errs[len(errs) // 2] if errs else None
    return {"rows": rows, "n": len(rows),
            "median_abs_rel_err": med,
            "max_abs_rel_err": errs[-1] if errs else None}


# --------------------------------------------------------------------------
# block-shape prefilter (core.autotune consumer)
# --------------------------------------------------------------------------

def predict_block_rows(nbytes: int, model, candidates, mix: str = "load_sum",
                       itemsize: int = 4, lanes: int = 128,
                       overhead_elems: float = BLOCK_OVERHEAD_ELEMS) -> dict:
    """Closed-form ECM ranking of block-row candidates: rows -> predicted
    GB/s.  The two penalties that make fig3's curve peaked:

    * capacity: the block tile (plus its companion stream — factor 2) must
      fit the innermost level, else the transfer path extends outward;
    * issue: per-block overhead charges small blocks on the core term.
    """
    from repro.bench.mixes import get_mix
    m = get_mix(mix)
    n = nbytes // max(itemsize, 1)
    rate = _issue_rate(model)
    declared = m.bytes_per_pass(nbytes)
    traffic_elems = (m.reads_per_elem + m.writes_per_elem) * n
    out = {}
    for rows in candidates:
        block_bytes = rows * lanes * itemsize
        nblocks = max(math.ceil(n / (rows * lanes)), 1)
        issue = traffic_elems + m.flops_per_elem * n + overhead_elems * nblocks
        t_core = issue / rate if rate else 0.0
        t_data = 0.0
        for lvl in model.level_path(max(nbytes, 2 * block_bytes)):
            bw = model.bandwidth_for(lvl, m.name)
            if bw:
                t_data += traffic_elems * itemsize / bw
        t = max(t_core, t_data)
        out[rows] = declared / t / 1e9 if t > 0 else 0.0
    return out


def ecm_filter_rows(nbytes: int, model, candidates, keep: int = 3,
                    mix: str = "load_sum", itemsize: int = 4) -> tuple:
    """(kept, predicted) — the top-``keep`` candidates by ECM-predicted
    throughput, in the original candidate order (the autotuner's timed
    sweep then runs only these)."""
    predicted = predict_block_rows(nbytes, model, candidates, mix=mix,
                                   itemsize=itemsize)
    ranked = sorted(predicted, key=predicted.get, reverse=True)[:max(keep, 1)]
    kept = tuple(r for r in candidates if r in ranked)
    return kept, predicted
