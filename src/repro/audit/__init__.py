"""repro.audit — static accounting verifier + ECM analytic predictor.

Two consumers of the compiled-IR extractor (``repro.istream.extract``) that
need no timing at all (see README.md here):

    verify   declared bytes/flops (the mix registry) vs observed compiled
             traffic, for every mix x backend x knob combination — with
             explicit detection of hoisted / dead-code-eliminated timed
             work and formula lint over the registry itself
    ecm      Execution-Cache-Memory-style per-pass time prediction from a
             profile + FittedMachineModel (issue term vs per-level transfer
             terms), validated against measurement (fig3) and consumed by
             ``core.autotune`` as a block-shape prefilter

Entry points: ``python -m repro.bench audit`` (CLI; exit 0 clean, 2 on an
accounting violation) and ``tests/test_audit.py`` (registry-parametrized
lint, runs deviceless off golden HLO fixtures in ``tests/data/hlo/``).
"""
from repro.audit.ecm import (EcmPrediction, ecm_filter_rows,  # noqa: F401
                             ecm_predict, predict_block_rows, validate_ecm)
from repro.audit.verify import (EXIT_OK, EXIT_VIOLATION,  # noqa: F401
                                AuditReport, CaseAudit, Check, audit_case,
                                audit_counts, audit_goldens, audit_hlo,
                                audit_registry, default_knob_grid,
                                expected_counts, lint_mix, random_rw_pairs,
                                waiver_reason, write_goldens)

__all__ = ["AuditReport", "CaseAudit", "Check", "EXIT_OK", "EXIT_VIOLATION",
           "EcmPrediction", "audit_case", "audit_counts", "audit_goldens",
           "audit_hlo", "audit_registry", "default_knob_grid",
           "ecm_filter_rows", "ecm_predict", "expected_counts", "lint_mix",
           "predict_block_rows", "random_rw_pairs", "validate_ecm",
           "waiver_reason", "write_goldens"]
