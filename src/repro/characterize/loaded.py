"""Loaded-latency surfaces — Mess-style bandwidth–latency curve fits.

The ``latency_chase`` mix measures per-step dependent-load latency and the
spec's ``load`` axis co-schedules bandwidth-generator streams next to the
probe (``bench/README.md``, "Loaded-latency surfaces").  Sweeping ``load``
at a fixed working-set size traces one bandwidth–latency curve — the Mess
benchmark's view of a memory level: latency sits on an idle plateau until
the generators approach the level's sustainable bandwidth, then takes off.

This module turns such sweeps into fitted summaries:

* ``loaded_latency_sweep`` — drive the Runner over (sizes x loads); one
  spec per load level (``load`` is a spec knob and a compiled-case cache
  key), merged by ``run_many`` into a single schema-v5 result.
* ``fit_knee`` — one curve's knee: the last load level whose latency stays
  within ``factor`` of the idle latency, and the generator bandwidth there
  (the measured sustainable-bandwidth point).
* ``fit_loaded`` — per-hierarchy-level knee fits in ``summarize`` band
  discipline; the dict stored on ``FittedMachineModel.loaded_latency``
  (fitted-model schema v3).
"""
from __future__ import annotations

import math


def loaded_latency_sweep(sizes, loads=(0, 1, 2, 4), *, backend: str = "xla",
                         runner=None, reps: int = 5, warmup: int = 1,
                         dtype: str = "float32", spec_kw: dict | None = None):
    """Measure ``latency_chase`` at every (size, load) point.

    ``load`` lives on the spec, so each load level is its own
    ``BenchSpec``; ``Runner.run_many`` merges them into one result whose
    points carry the curve coordinates (``load`` / ``latency_ns`` /
    ``gen_gbps``).  The single-device backends (xla / pallas) emulate the
    generators time-shared; on ``sharded`` the composite is spatial but
    ``devices == load + 1`` is required per spec, so sweep loads there by
    calling this once per load with ``spec_kw={"devices": load + 1}``.
    """
    from repro.bench import BenchSpec, Runner
    runner = runner or Runner()
    spec_kw = dict(spec_kw or {})
    specs = [BenchSpec(mixes=("latency_chase",), sizes=tuple(sizes),
                       backend=backend, dtype=dtype, reps=reps,
                       warmup=warmup, load=load, **spec_kw)
             for load in loads]
    res = runner.run_many(specs)
    res.meta["loaded_latency"] = {"loads": list(loads), "backend": backend}
    return res


def _curve(points) -> dict:
    """load -> (mean latency_ns, mean gen_gbps) over the chase points."""
    by_load: dict[int, dict] = {}
    for p in points:
        if getattr(p, "latency_ns", None) is None:
            continue
        cell = by_load.setdefault(p.load, {"lat": 0.0, "gen": 0.0, "n": 0})
        cell["lat"] += p.latency_ns
        cell["gen"] += p.gen_gbps or 0.0
        cell["n"] += 1
    return {load: (c["lat"] / c["n"], c["gen"] / c["n"])
            for load, c in sorted(by_load.items())}


def fit_knee(points, factor: float = 1.5) -> dict | None:
    """Fit one bandwidth–latency curve's knee from its chase points.

    The knee is the LAST load level whose mean latency stays within
    ``factor`` x the idle (lowest-load) latency — the measured sustainable
    operating point; ``knee_gen_gbps`` is the aggregate generator
    bandwidth there (0.0 when the knee is the idle point itself).  Points
    at the same load are averaged (multiple sizes / reps).  Returns None
    when fewer than two load levels are present (no curve to fit).
    """
    curve = _curve(points)
    if len(curve) < 2:
        return None
    loads = list(curve)
    lats = [curve[load][0] for load in loads]
    gens = [curve[load][1] for load in loads]
    idle = lats[0]
    knee_i = max((i for i, lat in enumerate(lats)
                  if lat <= factor * idle), default=0)
    return {"factor": factor,
            "idle_latency_ns": idle,
            "max_latency_ns": max(lats),
            "knee_load": loads[knee_i],
            "knee_gen_gbps": gens[knee_i],
            "loads": loads,
            "latency_ns": lats,
            "gen_gbps": gens}


def fit_loaded(result, levels=None, factor: float = 1.5,
               min_band_bytes: int = 4 * 2**10) -> dict | None:
    """Per-hierarchy-level knee fits over a loaded-latency sweep result.

    ``levels`` follows ``BenchResult.summarize``: an ordered sequence
    (innermost first) of ``(name, size_bytes)`` pairs or objects with
    ``.name`` / ``.size_bytes`` (``None`` size = unbounded); omitted means
    one ``"all"`` level.  Each level's knee is fitted from the chase
    points inside its attribution band (``result.level_band`` discipline,
    same as bandwidth attribution), so a sweep spanning L1-resident
    through DRAM-sized working sets yields one curve per level.

    Returns ``{"factor": ..., "levels": {name: knee_dict}}`` — the value
    stored on ``FittedMachineModel.loaded_latency`` — or None when no
    level has a fittable curve.  All-finite floats: JSON-safe by
    construction (band edges use None for unbounded).
    """
    from repro.bench.result import level_band
    chase = [p for p in result.points
             if getattr(p, "latency_ns", None) is not None]
    if levels is None:
        levels = (("all", None),)
    out: dict[str, dict] = {}
    prev = min_band_bytes / 2.0
    for lvl in levels:
        name, size = (lvl if isinstance(lvl, (tuple, list))
                      else (lvl.name, lvl.size_bytes))
        lo, hi = level_band(size, prev)
        knee = fit_knee([p for p in chase if lo <= p.nbytes <= hi],
                        factor=factor)
        if knee is not None:
            knee["band"] = [lo, None if math.isinf(hi) else hi]
            out[name] = knee
        if size:
            prev = size
    return {"factor": factor, "levels": out} if out else None
