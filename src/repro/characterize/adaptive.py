"""Adaptive fine-granularity sweep — the paper's resolution at a fraction
of the samples.

A dense grid fine enough to localize a cache boundary to ±10% needs
``log(hi/lo)/log(1.1)`` points across the whole span; almost all of them
land mid-plateau where they add nothing.  This driver starts from a coarse
log-spaced grid (``core.buffers.hierarchy_grid``), runs change-point
detection (``characterize.detect``), and each round measures ONLY geometric
midpoints inside still-unresolved boundary brackets — classic bisection, so
every round halves each bracket and convergence takes
``O(log(coarse_gap / resolution))`` rounds.

One ``bench.Runner`` lives across all rounds: its compiled-case cache means
a mix re-measured at an already-compiled shape re-times without re-tracing,
and candidate sizes are snapped to real working-set tiles
(``buffers.snap_sizes``) so the driver never re-times a size it already has
— a bracket that cannot produce a new snapped size is resolution-floored
and counts as converged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.characterize.detect import Detection, detect_levels

DEFAULT_RESOLUTION = 0.10       # relative boundary-bracket width target


@dataclass
class AdaptiveSweep:
    """Everything one adaptive characterization run measured and inferred."""
    result: object                  # merged BenchResult (all rounds)
    detection: Detection            # detection over the final point set
    rounds: int = 0
    resolution: float = DEFAULT_RESOLUTION
    history: list[dict] = field(default_factory=list)   # per-round summary

    @property
    def n_points(self) -> int:
        return len({p.nbytes for p in self.result.points})

    @property
    def converged(self) -> bool:
        return not self.detection.unresolved(self.resolution) or \
            bool(self.history and self.history[-1].get("floored"))

    def dense_equivalent(self, lo: int | None = None, hi: int | None = None
                         ) -> int:
        """Points a fixed grid would need for the same boundary resolution
        across [lo, hi] (the sample-count baseline the paper's fine
        granularity implies)."""
        sizes = sorted({p.nbytes for p in self.result.points})
        lo = lo or sizes[0]
        hi = hi or sizes[-1]
        return int(math.ceil(math.log(hi / lo)
                             / math.log(1.0 + self.resolution))) + 1

    def summary(self) -> dict:
        return {
            "rounds": self.rounds,
            "n_points": self.n_points,
            "dense_equivalent": self.dense_equivalent(),
            "resolution": self.resolution,
            "converged": self.converged,
            "n_levels": self.detection.n_levels,
            "history": self.history,
        }


def _bisection_candidates(detection: Detection, resolution: float,
                          measured: set[int], dtype) -> list[int]:
    """Geometric midpoints of every unresolved bracket, snapped to real
    working-set sizes and deduped against what's already measured."""
    from repro.core import buffers
    cands: list[int] = []
    for b in detection.unresolved(resolution):
        mid = int(round(math.sqrt(float(b.lo) * float(b.hi))))
        for c in buffers.snap_sizes([mid], dtype=dtype):
            if c not in measured and b.lo < c < b.hi:
                cands.append(c)
    return sorted(set(cands))


def adaptive_sweep(mix: str = "load_sum", *, runner=None, backend: str = "xla",
                   lo: int | None = None, hi: int | None = None,
                   coarse_per_decade: int = 3,
                   resolution: float = DEFAULT_RESOLUTION,
                   max_rounds: int = 8, reps: int = 5, warmup: int = 1,
                   target_bytes: float = 5e7, dtype: str = "float32",
                   spec_kw: dict | None = None, detect_kw: dict | None = None
                   ) -> AdaptiveSweep:
    """Run the adaptive refinement loop for one instruction mix.

    ``runner`` is duck-typed (needs ``.run(BenchSpec) -> BenchResult``); the
    tests inject a synthetic-curve runner, production passes a
    ``bench.Runner`` (or None for a fresh one, kept for all rounds so the
    compiled-case cache spans them).
    """
    import jax.numpy as jnp

    from repro.bench import BenchSpec, Runner
    from repro.core import buffers
    from repro.obs import metrics, trace

    if max_rounds < 1:
        raise ValueError(f"max_rounds must be >= 1: {max_rounds} "
                         f"(round 1 is the coarse grid)")
    lo = lo or buffers.HIERARCHY_SPAN[0]
    hi = hi or buffers.HIERARCHY_SPAN[1]
    runner = runner or Runner()
    detect_kw = dict(detect_kw or {})
    base = BenchSpec(mixes=(mix,), sizes=(lo,), backend=backend, dtype=dtype,
                     reps=reps, warmup=warmup, target_bytes=target_bytes,
                     **(spec_kw or {}))

    jdtype = jnp.dtype(dtype)
    sizes = buffers.size_grid(lo, hi, per_decade=coarse_per_decade,
                              dtype=jdtype)
    merged = None
    measured: set[int] = set()
    history: list[dict] = []
    detection = None
    rounds = 0
    tr = trace.get_tracer()
    while rounds < max_rounds:
        new = [s for s in sizes if s not in measured]
        if not new:
            break
        with tr.span("characterize.round", cat="characterize",
                     round=rounds + 1, mix=mix, new_points=len(new)):
            metrics.REGISTRY.inc("adaptive_rounds")
            res = runner.run(base.replace(sizes=tuple(new)))
            measured.update(p.nbytes for p in res.points)
            if merged is None:
                merged = res
            else:
                merged.points.extend(res.points)
                merged.meta["sizes"] = sorted({*merged.meta.get("sizes", []),
                                               *res.meta.get("sizes", [])})
            rounds += 1
            detection = detect_levels(
                sorted(measured),
                [_mean_gbps(merged, mix, s) for s in sorted(measured)],
                mix=mix, **detect_kw)
            unresolved = detection.unresolved(resolution)
            sizes = _bisection_candidates(detection, resolution, measured,
                                          jdtype)
            floored = bool(unresolved) and not sizes
            tr.event("characterize.bisect", cat="characterize",
                     round=rounds, n_levels=detection.n_levels,
                     brackets=[[b.lo, b.hi] for b in unresolved],
                     candidates=sizes, floored=floored)
        history.append({
            "round": rounds, "new_points": len(new),
            "n_levels": detection.n_levels,
            "unresolved": len(unresolved),
            "brackets": [[b.lo, b.hi] for b in unresolved],
            "floored": floored,     # bracket narrower than one buffer tile
        })
        if not unresolved or floored:
            break
    merged.meta["characterize"] = {"mix": mix, "rounds": rounds,
                                   "resolution": resolution,
                                   "span": [lo, hi],
                                   "coarse_per_decade": coarse_per_decade}
    return AdaptiveSweep(result=merged, detection=detection, rounds=rounds,
                         resolution=resolution, history=history)


def _mean_gbps(res, mix: str, nbytes: int) -> float:
    pts = [p.gbps for p in res.points if p.mix == mix and p.nbytes == nbytes]
    return float(sum(pts) / len(pts))
