"""FittedMachineModel — the measurement-derived machine model.

The paper's deliverable is not a curve but a *characterization*: how many
levels, how big, how fast under each instruction mix, where the measured
numbers disagree with the documentation (Table 1).  This module assembles
that from detection output:

* ``fit_from_result`` — BenchResult (+ Detection, or documented/prior
  ``HardwareSpec`` levels) -> ``FittedMachineModel``: per-level per-mix
  bandwidths, mix penalties, measured ridge point, all schema-versioned.
* ``characterize`` — the full pipeline: adaptive sweep on a primary mix,
  secondary mixes probed only at plateau-interior sizes (one of the sample
  savings: topology is found once, mixes ride on it), sysfs prior
  cross-check, fit.
* The fitted model registers into the ``core.machine_model`` spec registry
  (``model.register()``) and is accepted by ``roofline.analyze`` (as the
  machine constants) and ``core.autotune`` (as the capacity that bounds
  block candidates) in place of the static tables.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

from repro.characterize.adaptive import AdaptiveSweep, adaptive_sweep
from repro.characterize.detect import Detection, detect_from_result
from repro.core.machine_model import (HardwareSpec, MachineModel, MemLevel,
                                      detect_host, register_spec)

# schema history: 1 = levels/penalties/ridge/prior/provenance; 2 = optional
# ``issue`` dict — the fitted instruction-issue model (``rate_elems_per_s``
# + fit provenance) that ``repro.istream`` classifies against; 3 = optional
# ``loaded_latency`` dict — per-level bandwidth–latency knee fits from a
# loaded-latency sweep (``characterize.loaded.fit_loaded``).  Older files
# load unchanged (the optional fields stay None).
FITTED_SCHEMA_VERSION = 3


@dataclass(frozen=True)
class LevelFit:
    """One hierarchy level with everything measured about it."""
    name: str
    capacity_bytes: Optional[int]             # None = unbounded / outermost
    capacity_ci: Optional[tuple[int, int]]    # measured bracket; None if
    #   capacity came from a documented table rather than detection
    bandwidth: dict = field(default_factory=dict)
    #   mix -> {"gbps": float, "ci": (lo, hi) | None, "n": int}

    @property
    def best_gbps(self) -> float:
        return max((c["gbps"] for c in self.bandwidth.values()), default=0.0)

    @property
    def best_mix(self) -> Optional[str]:
        if not self.bandwidth:
            return None
        return max(self.bandwidth, key=lambda m: self.bandwidth[m]["gbps"])


@dataclass
class FittedMachineModel:
    """Schema-versioned, JSON-round-trippable fitted model of one machine."""
    name: str = "host-cpu-fitted"
    levels: tuple[LevelFit, ...] = ()
    ridge_flops_per_byte: Optional[float] = None
    mix_penalty: dict = field(default_factory=dict)   # level -> {mix: rel}
    sysfs_prior: Optional[dict] = None    # {"levels": [...], "crosscheck": [..]}
    provenance: dict = field(default_factory=dict)    # sweep economics + meta
    issue: Optional[dict] = None    # schema v2: fitted issue model —
    #   {"rate_elems_per_s": float, ...fit provenance}; repro.istream both
    #   fits it (fit_issue_rate) and classifies against it
    loaded_latency: Optional[dict] = None   # schema v3: per-level
    #   bandwidth–latency knee fits — {"factor", "levels": {name:
    #   {"idle_latency_ns", "knee_load", "knee_gen_gbps", ...curve}}}
    #   from characterize.loaded.fit_loaded over a latency_chase sweep
    schema_version: int = FITTED_SCHEMA_VERSION

    def __post_init__(self):
        self.levels = tuple(
            l if isinstance(l, LevelFit) else LevelFit(
                name=l["name"], capacity_bytes=l["capacity_bytes"],
                capacity_ci=(tuple(l["capacity_ci"])
                             if l.get("capacity_ci") else None),
                bandwidth={m: {**c, "ci": tuple(c["ci"]) if c.get("ci")
                               else None}
                           for m, c in l.get("bandwidth", {}).items()})
            for l in self.levels)

    # -- consumers ----------------------------------------------------------
    @property
    def peak_flops(self) -> Optional[float]:
        """Measured models carry no documented FLOP peak (None convention)."""
        return self.provenance.get("peak_flops")

    @property
    def hbm_bw(self) -> Optional[float]:
        """Outermost-level best measured bandwidth in B/s — the roofline's
        memory-term denominator."""
        if not self.levels:
            return None
        bw = self.levels[-1].best_gbps
        return bw * 1e9 if bw else None

    @property
    def innermost_capacity(self) -> Optional[int]:
        """Detected capacity of the innermost level — what the autotuner
        sizes blocks against."""
        for l in self.levels:
            if l.capacity_bytes:
                return l.capacity_bytes
        return None

    @property
    def issue_rate(self) -> Optional[float]:
        """Fitted sustained issue rate (element-ops/s, schema v2) — the
        ECM predictor's in-core term denominator."""
        return (self.issue or {}).get("rate_elems_per_s")

    def level_path(self, nbytes: int) -> list[LevelFit]:
        """Hierarchy prefix a working set of ``nbytes`` streams through:
        innermost level up to (and including) its residence level — the
        first level whose measured capacity holds it, else the outermost.
        The ECM predictor sums per-level transfer times over this path."""
        path: list[LevelFit] = []
        for l in self.levels:
            path.append(l)
            if l.capacity_bytes and nbytes <= l.capacity_bytes:
                break
        return path

    def bandwidth_for(self, level: LevelFit, mix: str | None = None
                      ) -> Optional[float]:
        """Measured bandwidth of ``level`` in B/s — the mix's own cell when
        measured there, else the level's best mix (penalties are already a
        separate field; the ECM consumer wants an absolute number)."""
        cell = level.bandwidth.get(mix) if mix else None
        gbps = cell["gbps"] if cell else level.best_gbps
        return gbps * 1e9 if gbps else None

    def to_hardware_spec(self) -> HardwareSpec:
        """Detected topology as a HardwareSpec (measured best-mix bandwidth
        in the ``read_bw`` slot, B/s) — drop-in for the static tables."""
        return HardwareSpec(
            name=self.name, peak_flops=self.peak_flops,
            levels=tuple(MemLevel(l.name, l.capacity_bytes,
                                  l.best_gbps * 1e9 if l.bandwidth else None)
                         for l in self.levels),
            notes="measured by repro.characterize")

    def to_machine_model(self) -> MachineModel:
        """Downgrade to the legacy MachineModel shape consumed by
        ``core.analysis`` callers and the table1 benchmark."""
        return MachineModel(
            hardware={"name": self.name,
                      "levels": [(l.name, l.capacity_bytes,
                                  l.best_gbps * 1e9 if l.bandwidth else None)
                                 for l in self.levels]},
            level_bw={l.name: {m: c["gbps"] for m, c in l.bandwidth.items()}
                      for l in self.levels if l.bandwidth},
            ridge_flops_per_byte=self.ridge_flops_per_byte,
            mix_penalty=self.mix_penalty)

    def register(self, overwrite: bool = True) -> HardwareSpec:
        """Publish the detected topology into the machine_model registry so
        ``get_spec(self.name)`` resolves to measurement, like the tables."""
        return register_spec(self.to_hardware_spec(), overwrite=overwrite)

    # -- measured vs documented (the paper's Table-1 deltas) ---------------
    def compare_to(self, documented: HardwareSpec) -> dict:
        """Per-level measured-vs-documented report: capacity and bandwidth
        deltas, level-count mismatch, prior containment."""
        rows = []
        for i in range(max(len(self.levels), len(documented.levels))):
            det = self.levels[i] if i < len(self.levels) else None
            doc = documented.levels[i] if i < len(documented.levels) else None
            row = {"detected": det.name if det else None,
                   "documented": doc.name if doc else None}
            if det and doc:
                if det.capacity_bytes and doc.size_bytes:
                    row["capacity_bytes"] = det.capacity_bytes
                    row["documented_bytes"] = doc.size_bytes
                    row["capacity_ratio"] = det.capacity_bytes / doc.size_bytes
                    row["capacity_within_ci"] = (
                        det.capacity_ci is not None
                        and det.capacity_ci[0] <= doc.size_bytes
                        <= det.capacity_ci[1])
                if det.bandwidth and doc.read_bw:
                    row["gbps"] = det.best_gbps
                    row["documented_gbps"] = doc.read_bw / 1e9
                    row["bw_ratio"] = det.best_gbps / (doc.read_bw / 1e9)
            rows.append(row)
        return {"name": self.name, "documented_name": documented.name,
                "n_detected": len(self.levels),
                "n_documented": len(documented.levels),
                "levels": rows}

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "name": self.name,
            "levels": [{
                "name": l.name, "capacity_bytes": l.capacity_bytes,
                "capacity_ci": list(l.capacity_ci) if l.capacity_ci else None,
                "bandwidth": {m: {"gbps": c["gbps"],
                                  "ci": list(c["ci"]) if c.get("ci") else None,
                                  "n": c.get("n", 0)}
                              for m, c in l.bandwidth.items()},
            } for l in self.levels],
            "ridge_flops_per_byte": self.ridge_flops_per_byte,
            "mix_penalty": self.mix_penalty,
            "sysfs_prior": self.sysfs_prior,
            "provenance": self.provenance,
            "issue": self.issue,
            "loaded_latency": self.loaded_latency,
        }

    def to_json(self, path: str | Path | None = None) -> str:
        s = json.dumps(self.to_dict(), indent=2)
        if path is not None:
            Path(path).write_text(s)
        return s

    @classmethod
    def from_dict(cls, d: dict) -> "FittedMachineModel":
        d = dict(d)
        ver = d.pop("schema_version", FITTED_SCHEMA_VERSION)
        if ver > FITTED_SCHEMA_VERSION:
            raise ValueError(f"fitted-model schema {ver} newer than "
                             f"supported {FITTED_SCHEMA_VERSION}")
        return cls(**d, schema_version=ver)

    @classmethod
    def from_json(cls, src: str | Path) -> "FittedMachineModel":
        return cls.from_dict(json.loads(Path(src).read_text()))


# --------------------------------------------------------------------------
# fitting
# --------------------------------------------------------------------------

def _band_cells(res, levels) -> dict:
    """{level: {mix: {"gbps", "n", "ci"}}} via BenchResult.summarize bands
    (unbound call: duck-typed for legacy SweepResult, like core.analysis)."""
    from repro.bench.result import BenchResult
    summary = BenchResult.summarize(res, levels=levels)
    out = {}
    for lvl, mixes in summary.items():
        out[lvl] = {m: {"gbps": c["gbps"], "n": c["n"], "ci": None}
                    for m, c in mixes.items()}
    return out


def _ridge(res, band) -> Optional[float]:
    from repro.core.analysis import ridge_depth
    if not hasattr(res, "by_mix"):     # bare point container (tests inject
        from repro.bench.result import BenchResult   # synthetic runners)
        shim = BenchResult(points=list(res.points))
        shim.meta = dict(getattr(res, "meta", {}) or {})
        res = shim
    k = ridge_depth(res, band)
    if k is None:
        return None
    itemsize = 4
    meta_dtype = res.meta.get("dtype", "float32") if hasattr(res, "meta") \
        else "float32"
    if isinstance(meta_dtype, str) and meta_dtype in ("bfloat16", "float16"):
        itemsize = 2
    return 2.0 * k / itemsize


def fit_from_result(res, detection: Detection | None = None,
                    hw: HardwareSpec | None = None, mix: str | None = None,
                    name: str | None = None) -> FittedMachineModel:
    """Fit a model from a finished sweep.

    Two modes:
    * ``hw`` given — *prior/documented banding*: per-mix bandwidths are
      attributed inside ``hw``'s level bands (the legacy
      ``core.analysis.build_machine_model`` path, now a wrapper over this).
      Capacities are the documented ones; no detection CI.
    * ``hw`` omitted — *detected banding*: levels come from change-point
      detection over the primary mix's curve (``detection`` if supplied,
      else run here); capacities carry measured brackets.
    """
    from repro.bench.result import level_band

    if hw is not None:
        levels_src = [(l.name, l.size_bytes, None, None) for l in hw.levels]
        band_levels = hw.levels
        name = name or f"{hw.name}-fitted"
        detection_dict = None
    else:
        if detection is None:
            detection = detect_from_result(res, mix=mix)
        levels_src = [(l.name, l.capacity_bytes, l.capacity_ci, l.gbps_ci)
                      for l in detection.levels]
        band_levels = [(l.name, l.capacity_bytes) for l in detection.levels]
        name = name or "host-cpu-fitted"
        detection_dict = detection.to_dict()

    cells = _band_cells(res, band_levels)
    if detection is not None and hw is None:
        for l in detection.levels:
            cell = cells.get(l.name, {}).get(detection.mix)
            if cell is not None:
                # detection CI on the primary mix's plateau mean rides along
                cell["ci"] = l.gbps_ci
            else:
                # band attribution can come up empty for a level whose
                # detected capacity is below 2x the smallest measured size
                # (band hi = 0.5 cap < grid lo) — the detection plateau
                # stats ARE that level's primary-mix measurement, keep them
                cells.setdefault(l.name, {})[detection.mix] = {
                    "gbps": l.gbps, "n": l.n_points, "ci": l.gbps_ci}

    fits = []
    for lname, cap, cap_ci, _gci in levels_src:
        fits.append(LevelFit(name=lname, capacity_bytes=cap,
                             capacity_ci=cap_ci,
                             bandwidth=cells.get(lname, {})))

    penalty = {lvl: {m: c["gbps"] / best for m, c in mixes.items()}
               for lvl, mixes in cells.items()
               if (best := max(cc["gbps"] for cc in mixes.values()))}

    # ridge measured in the innermost level band (cache-resident)
    first_cap = next((cap for _, cap, _, _ in levels_src if cap), None)
    ridge = _ridge(res, level_band(first_cap, 2 * 2**10)) \
        if first_cap or levels_src else None

    prov = {"schema": "repro.characterize", "source_points": len(res.points)}
    if hasattr(res, "meta") and isinstance(getattr(res, "meta", None), dict):
        prov["sweep_meta"] = {k: res.meta[k] for k in
                              ("mixes", "dtype", "characterize")
                              if k in res.meta}
    if detection_dict:
        prov["detection"] = detection_dict
    return FittedMachineModel(name=name, levels=tuple(fits),
                              ridge_flops_per_byte=ridge,
                              mix_penalty=penalty, provenance=prov)


def crosscheck_prior(detection: Detection, prior: HardwareSpec) -> dict:
    """sysfs topology vs detected boundaries: for each prior cache size,
    is it inside a measured boundary bracket (and how far off otherwise)?"""
    checks = []
    brackets = [(b.lo, b.hi, b.capacity) for b in detection.boundaries]
    for lvl in prior.levels:
        if not lvl.size_bytes:
            continue
        hit = next(((lo, hi, cap) for lo, hi, cap in brackets
                    if lo <= lvl.size_bytes <= hi), None)
        if hit:
            checks.append({"prior": lvl.name, "size_bytes": lvl.size_bytes,
                           "within_bracket": True, "bracket": [hit[0], hit[1]]})
        else:
            nearest = min((cap for _, _, cap in brackets), default=None,
                          key=lambda c: abs(math.log(c / lvl.size_bytes))
                          if c else math.inf)
            checks.append({"prior": lvl.name, "size_bytes": lvl.size_bytes,
                           "within_bracket": False,
                           "nearest_detected": nearest,
                           "ratio": (nearest / lvl.size_bytes)
                           if nearest else None})
    return {"prior_name": prior.name, "notes": prior.notes, "checks": checks}


def probe_sizes(detection: Detection) -> list[int]:
    """One size per detected level for secondary mixes, picked inside the
    level's *attribution band* (``result.level_band``: 2x previous capacity
    to 0.5x own capacity) so ``summarize`` credits it — already-measured
    sizes, so the Runner's compiled-case cache turns these into re-times."""
    from repro.bench.result import level_band
    out = []
    prev = 2.0 * 2**10          # summarize's default min_band_bytes / 2
    for l in detection.levels:
        lo, hi = level_band(l.capacity_bytes, prev)
        if l.capacity_bytes:
            prev = l.capacity_bytes
        if not l.sizes:
            continue
        center = math.sqrt(lo * hi) if math.isfinite(hi) else 2.0 * lo
        inside = [s for s in l.sizes if lo <= s <= hi]
        if not inside:
            # no measured size falls in this level's band (capacity below
            # 2x the grid floor): a probe here would be timed and then
            # dropped by summarize — skip it; the level keeps its
            # detection-derived primary-mix cell (see fit_from_result)
            continue
        out.append(min(inside, key=lambda s: abs(math.log(s / center))))
    return sorted(set(out))


def characterize(mixes=("load_sum", "copy", "fma_8", "fma_32"),
                 primary: str = "load_sum", *, runner=None,
                 backend: str = "xla", name: str = "host-cpu-fitted",
                 register: bool = True, prior: HardwareSpec | None = None,
                 **adaptive_kw) -> tuple[FittedMachineModel, AdaptiveSweep]:
    """The full measurement->inference pipeline.

    1. adaptive boundary-refining sweep on ``primary``
    2. secondary ``mixes`` measured only at plateau-interior probe sizes
    3. fit + sysfs-prior cross-check + registry publication
    """
    from repro.bench import Runner
    runner = runner or Runner()
    if primary not in mixes:
        mixes = (primary, *mixes)
    sweep = adaptive_sweep(primary, runner=runner, backend=backend,
                           **adaptive_kw)
    secondary = tuple(m for m in mixes if m != primary)
    if secondary:
        probes = probe_sizes(sweep.detection)
        if probes:
            spec_kw = adaptive_kw.get("spec_kw") or {}
            from repro.bench import BenchSpec
            spec = BenchSpec(
                mixes=secondary, sizes=tuple(probes), backend=backend,
                dtype=adaptive_kw.get("dtype", "float32"),
                reps=adaptive_kw.get("reps", 5),
                warmup=adaptive_kw.get("warmup", 1),
                target_bytes=adaptive_kw.get("target_bytes", 5e7), **spec_kw)
            res2 = runner.run(spec)
            sweep.result.points.extend(res2.points)
            sweep.result.meta["mixes"] = list(mixes)
    model = fit_from_result(sweep.result, detection=sweep.detection,
                            name=name)
    model.provenance["sweep"] = sweep.summary()
    model.provenance["backend"] = backend
    prior = prior if prior is not None else detect_host()
    model.sysfs_prior = crosscheck_prior(sweep.detection, prior)
    if register:
        model.register()
    return model, sweep
