"""Characterization report — the paper's §5-§6 narrative as markdown/JSON.

Renders one FittedMachineModel (+ the adaptive sweep that produced it) as:
level table with capacity brackets and per-mix bandwidth CIs, mix-penalty
ratios, measured ridge point, sysfs-prior cross-check, measured-vs-documented
comparison (the Table-1 deltas), and the sweep economics (adaptive points vs
the dense grid the same resolution would have cost).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.characterize.fit import FittedMachineModel
from repro.core.machine_model import HardwareSpec


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if n >= div:
            return f"{n / div:.1f} {unit}".replace(".0 ", " ")
    return f"{n} B"


def _fmt_ci(ci) -> str:
    if not ci:
        return "-"
    return f"[{ci[0]:.1f}, {ci[1]:.1f}]"


def render_markdown(model: FittedMachineModel, sweep=None,
                    documented: HardwareSpec | None = None) -> str:
    lines = [f"# Machine characterization: `{model.name}`", ""]
    prov = model.provenance
    if prov.get("backend"):
        lines.append(f"backend: `{prov['backend']}` · "
                     f"points: {prov.get('source_points', '?')}")
        lines.append("")

    lines += ["## Detected hierarchy (measurement only — no sysfs, no docs)",
              "",
              "| level | capacity | bracket | best mix | GB/s | CI |",
              "|---|---|---|---|---|---|"]
    for l in model.levels:
        br = (f"{_fmt_bytes(l.capacity_ci[0])} … {_fmt_bytes(l.capacity_ci[1])}"
              if l.capacity_ci else "-")
        best = l.best_mix
        cell = l.bandwidth.get(best) if best else None
        lines.append(
            f"| {l.name} | {_fmt_bytes(l.capacity_bytes)} | {br} "
            f"| {best or '-'} | {cell['gbps']:.2f} "
            f"| {_fmt_ci(cell.get('ci'))} |" if cell else
            f"| {l.name} | {_fmt_bytes(l.capacity_bytes)} | {br} | - | - | - |")
    lines.append("")

    if model.mix_penalty:
        lines += ["## Per-level instruction-mix bandwidth (GB/s, rel to best)",
                  ""]
        mixes: list[str] = []
        for cells in (l.bandwidth for l in model.levels):
            mixes.extend(m for m in cells if m not in mixes)
        lines.append("| level | " + " | ".join(mixes) + " |")
        lines.append("|---|" + "---|" * len(mixes))
        for l in model.levels:
            row = [l.name]
            for m in mixes:
                c = l.bandwidth.get(m)
                rel = model.mix_penalty.get(l.name, {}).get(m)
                row.append(f"{c['gbps']:.1f} ({rel:.2f})" if c else "-")
            lines.append("| " + " | ".join(row) + " |")
        lines.append("")

    if model.ridge_flops_per_byte:
        lines += [f"measured ridge point: "
                  f"**{model.ridge_flops_per_byte:.1f} flop/B**", ""]

    if sweep is not None:
        s = sweep.summary() if hasattr(sweep, "summary") else sweep
        lines += ["## Sweep economics (adaptive vs dense)",
                  "",
                  f"- rounds: {s['rounds']}, measured sizes: {s['n_points']}",
                  f"- dense grid at the same {s['resolution']:.0%} boundary "
                  f"resolution: ~{s['dense_equivalent']} sizes "
                  f"({s['n_points'] / max(s['dense_equivalent'], 1):.0%} "
                  f"of the samples)",
                  f"- converged: {s['converged']}", ""]

    if model.sysfs_prior and model.sysfs_prior.get("checks"):
        lines += ["## sysfs prior cross-check (prior ONLY — detection is "
                  "authoritative)", "",
                  "| prior level | size | inside measured bracket? | note |",
                  "|---|---|---|---|"]
        for c in model.sysfs_prior["checks"]:
            if c["within_bracket"]:
                note = f"bracket {_fmt_bytes(c['bracket'][0])} … " \
                       f"{_fmt_bytes(c['bracket'][1])}"
            elif c.get("nearest_detected"):
                note = f"nearest detected {_fmt_bytes(c['nearest_detected'])}" \
                       f" ({c['ratio']:.2f}x)"
            else:
                note = "no boundary detected"
            lines.append(f"| {c['prior']} | {_fmt_bytes(c['size_bytes'])} "
                         f"| {'yes' if c['within_bracket'] else 'NO'} "
                         f"| {note} |")
        lines.append("")

    if documented is not None:
        cmp = model.compare_to(documented)
        lines += [f"## Measured vs documented: `{documented.name}` "
                  f"(the paper's Table-1 deltas)", "",
                  f"levels: detected {cmp['n_detected']} vs documented "
                  f"{cmp['n_documented']}", "",
                  "| detected | documented | capacity (meas/doc) | "
                  "BW GB/s (meas/doc) |",
                  "|---|---|---|---|"]
        for r in cmp["levels"]:
            capc = (f"{_fmt_bytes(r['capacity_bytes'])} / "
                    f"{_fmt_bytes(r['documented_bytes'])} "
                    f"({r['capacity_ratio']:.2f}x)"
                    if "capacity_ratio" in r else "-")
            bwc = (f"{r['gbps']:.1f} / {r['documented_gbps']:.1f} "
                   f"({r['bw_ratio']:.2f}x)" if "bw_ratio" in r else "-")
            lines.append(f"| {r['detected'] or '-'} | {r['documented'] or '-'} "
                         f"| {capc} | {bwc} |")
        lines.append("")
    return "\n".join(lines)


def render_json(model: FittedMachineModel, sweep=None,
                documented: HardwareSpec | None = None) -> dict:
    out = {"model": model.to_dict()}
    if sweep is not None:
        out["sweep"] = sweep.summary() if hasattr(sweep, "summary") else sweep
    if documented is not None:
        out["compare"] = model.compare_to(documented)
    return out


def write_report(model: FittedMachineModel, path: str | Path, sweep=None,
                 documented: HardwareSpec | None = None) -> Path:
    path = Path(path)
    if path.suffix == ".json":
        path.write_text(json.dumps(render_json(model, sweep, documented),
                                   indent=2))
    else:
        path.write_text(render_markdown(model, sweep, documented))
    return path
