"""Change-point / plateau detection over a measured GB/s-vs-size curve.

The paper reads cache sizes and per-level bandwidths off the throughput
curve by eye (§5-§6, 'fine spatial granularity'); this module does the same
inference mechanically, with NO sysfs or documentation input:

1. optimal piecewise-constant segmentation of log-bandwidth vs log-size
   (exact dynamic program, BIC-style penalty — the curve is a staircase:
   one plateau per hierarchy level, separated by capacity cliffs),
2. merge of adjacent segments whose plateau bandwidths are closer than the
   noise floor (``min_drop``) — a transition sample must not fake a level,
3. per-plateau bandwidth with a normal-approximation confidence interval,
   and per-boundary capacity with an interval bracketed by the last sample
   of one plateau and the first sample of the next (the *measured* bracket:
   exactly what adaptive refinement tightens).

Everything is plain numpy on (sizes, gbps) arrays; ``detect_from_result``
adapts a BenchResult.  The adaptive driver calls this every round and
bisects any ``Boundary`` whose bracket is wider than the target resolution.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Boundary:
    """One capacity transition: bracketed by measured sizes lo < hi."""
    lo: int                  # last working-set size on the inner plateau
    hi: int                  # first working-set size on the outer plateau
    capacity: int            # point estimate: geometric mean of the bracket

    @property
    def width(self) -> float:
        """Relative bracket width (hi/lo - 1); the adaptive driver's
        convergence measure."""
        return self.hi / self.lo - 1.0

    def resolved(self, resolution: float) -> bool:
        return self.width <= resolution


@dataclass(frozen=True)
class DetectedLevel:
    """One inferred hierarchy level: a bandwidth plateau."""
    name: str
    capacity_bytes: Optional[int]            # None = outermost (unbounded)
    capacity_ci: Optional[tuple[int, int]]   # measured bracket (lo, hi)
    gbps: float                              # plateau mean
    gbps_ci: tuple[float, float]             # normal-approx CI on the mean
    n_points: int
    sizes: tuple[int, ...]                   # member working-set sizes


@dataclass
class Detection:
    """Full detection result for one mix's size sweep."""
    levels: list[DetectedLevel] = field(default_factory=list)
    boundaries: list[Boundary] = field(default_factory=list)
    mix: str = ""
    n_points: int = 0

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def unresolved(self, resolution: float) -> list[Boundary]:
        return [b for b in self.boundaries if not b.resolved(resolution)]

    def to_dict(self) -> dict:
        return {
            "mix": self.mix, "n_points": self.n_points,
            "levels": [{
                "name": l.name, "capacity_bytes": l.capacity_bytes,
                "capacity_ci": list(l.capacity_ci) if l.capacity_ci else None,
                "gbps": l.gbps, "gbps_ci": list(l.gbps_ci),
                "n_points": l.n_points, "sizes": list(l.sizes),
            } for l in self.levels],
            "boundaries": [{"lo": b.lo, "hi": b.hi, "capacity": b.capacity}
                           for b in self.boundaries],
        }


def _segment_dp(y: np.ndarray, max_segments: int, penalty: float
                ) -> list[tuple[int, int]]:
    """Exact minimum of sum of within-segment squared error + penalty per
    extra segment (Bellman DP, O(n^2 k) — sweeps are tens of points)."""
    n = len(y)
    pre = np.concatenate([[0.0], np.cumsum(y)])
    pre2 = np.concatenate([[0.0], np.cumsum(y * y)])

    def sse(i, j):          # cost of one segment y[i:j]
        s, s2, m = pre[j] - pre[i], pre2[j] - pre2[i], j - i
        return s2 - s * s / m

    kmax = min(max_segments, n)
    # cost[k][j] = best cost of y[:j] split into k+1 segments
    cost = np.full((kmax, n + 1), np.inf)
    back = np.zeros((kmax, n + 1), dtype=int)
    for j in range(1, n + 1):
        cost[0][j] = sse(0, j)
    for k in range(1, kmax):
        for j in range(k + 1, n + 1):
            cands = [cost[k - 1][i] + sse(i, j) for i in range(k, j)]
            best = int(np.argmin(cands))
            cost[k][j] = cands[best]
            back[k][j] = best + k
    # pick segment count by penalized cost
    totals = [cost[k][n] + penalty * k for k in range(kmax)]
    k = int(np.argmin(totals))
    # reconstruct
    bounds = [n]
    j = n
    for kk in range(k, 0, -1):
        j = back[kk][j]
        bounds.append(j)
    bounds.append(0)
    bounds = bounds[::-1]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def significant_step(m1: float, n1: int, m2: float, n2: int, *,
                     sigma: float, z: float = 3.0, min_drop: float = 0.12
                     ) -> bool:
    """The noise-aware two-sample test: is the gap between two log-scale
    means (``n1``/``n2`` samples each, common noise scale ``sigma``) a real
    step, or noise?

    The gap must clear BOTH the physical floor ``log(1+min_drop)`` (a
    smaller relative step does not count, however many samples agree on it)
    and the sampling bound ``z·σ·√(1/n₁+1/n₂)`` (few-sample means need a
    bigger gap).  Shared by the plateau merger below (a non-significant
    step between adjacent segments merges them) and the run ledger's
    regression gate (``obs.ledger.diff_records`` — a significant drop in a
    bandwidth cell is a regression), so the detector and the gate cannot
    disagree about what counts as noise.
    """
    thr = max(math.log(1.0 + min_drop),
              z * sigma * math.sqrt(1.0 / max(n1, 1) + 1.0 / max(n2, 1)))
    return abs(m1 - m2) >= thr


def _merge_segments(segs, y: np.ndarray, *, min_drop: float, sigma: float,
                    z: float = 3.0) -> list[tuple[int, int]]:
    """Iteratively merge adjacent segments the data can't tell apart.

    Two rules, applied closest-pair-first until a fixpoint (means are
    recomputed after every merge; callers pass the median-filtered series
    with the RAW noise sigma — see ``detect_levels``):

    * indistinguishable: |Δmean| fails ``significant_step`` — below both
      the physical floor (``log(1+min_drop)`` — a smaller step is noise,
      not a hierarchy level) and the two-sample noise bound
      ``z·σ·√(1/n₁+1/n₂)`` (short plateau fragments need a bigger gap to
      count as real),
    * non-physical: the OUTER segment is *faster* — bandwidth cannot rise
      with working-set size, so an upward step is measurement noise and the
      pair is one plateau.
    """
    segs = list(segs)

    def mean(seg):
        return float(np.mean(y[seg[0]:seg[1]]))

    while len(segs) > 1:
        best_i, best_d = None, None
        for i in range(len(segs) - 1):
            a, b = segs[i], segs[i + 1]
            m1, m2 = mean(a), mean(b)
            sig = significant_step(m1, a[1] - a[0], m2, b[1] - b[0],
                                   sigma=sigma, z=z, min_drop=min_drop)
            d = abs(m1 - m2)
            if (not sig or m2 > m1) and (best_d is None or d < best_d):
                best_i, best_d = i, d
        if best_i is None:
            break
        a, b = segs[best_i], segs[best_i + 1]
        segs[best_i:best_i + 2] = [(a[0], b[1])]
    return segs


def _noise_sigma(y: np.ndarray) -> float:
    """Robust noise scale from first differences (MAD estimator) — plateau
    interiors are flat, so diffs are ~noise except at the few cliffs, which
    the median ignores."""
    if len(y) < 3:
        return 0.05
    d = np.abs(np.diff(y))
    sigma = 1.4826 * float(np.median(d)) / math.sqrt(2.0)
    return max(sigma, 1e-3)


def detect_levels(sizes: Sequence[int], gbps: Sequence[float], *,
                  max_levels: int = 6, min_drop: float = 0.12,
                  z: float = 1.96, mix: str = "") -> Detection:
    """Infer hierarchy levels from a (working-set size, throughput) sweep.

    ``min_drop``: smallest relative bandwidth step that counts as a level
    transition (smaller steps are merged — measurement noise, not topology).
    ``z``: normal quantile for the plateau-bandwidth CI (1.96 = 95%).
    """
    if len(sizes) != len(gbps) or len(sizes) == 0:
        raise ValueError("sizes and gbps must be equal-length, non-empty")
    order = np.argsort(np.asarray(sizes))
    s = np.asarray(sizes, dtype=np.int64)[order]
    g = np.asarray(gbps, dtype=np.float64)[order]
    if np.any(g <= 0):
        raise ValueError("gbps must be positive (a 0.0 point is a failed "
                         "measurement, not a plateau)")
    n = len(s)
    y = np.log(g)

    # light median filter: a lone mid-transition sample joins a neighbor
    # plateau instead of becoming a one-point segment
    ys = y.copy()
    if n >= 5:
        for i in range(1, n - 1):
            ys[i] = np.median(y[i - 1:i + 2])

    # two noise scales: the RAW sigma calibrates the merge threshold (what a
    # real plateau gap must exceed), the FILTERED sigma the DP penalty (the
    # DP runs on the filtered series) — using the filtered sigma for both
    # under-estimates noise and lets 2-point noise excursions survive as
    # fake levels (measured: 7/60 wrong level counts vs 0/60 at 6% noise)
    sigma_raw = _noise_sigma(y)
    sigma_f = _noise_sigma(ys)
    penalty = max(2.0 * sigma_f * sigma_f * math.log(max(n, 2)),
                  0.25 * math.log(1.0 + min_drop) ** 2)
    segs = _segment_dp(ys, max_segments=max_levels + 2, penalty=penalty)

    merged = _merge_segments(segs, ys, min_drop=min_drop, sigma=sigma_raw)

    det = Detection(mix=mix, n_points=n)
    for li, (a, b) in enumerate(merged):
        pts = g[a:b]
        mean = float(np.mean(pts))
        if len(pts) > 1:
            half = z * float(np.std(pts, ddof=1)) / math.sqrt(len(pts))
        else:
            half = min_drop * mean      # single sample: noise-floor interval
        last = li == len(merged) - 1
        cap_ci = (int(s[b - 1]), int(s[b])) if not last else None
        cap = (int(round(math.sqrt(cap_ci[0] * cap_ci[1])))
               if cap_ci else None)
        det.levels.append(DetectedLevel(
            name="DRAM" if last else f"L{li + 1}",
            capacity_bytes=cap, capacity_ci=cap_ci,
            gbps=mean, gbps_ci=(mean - half, mean + half),
            n_points=len(pts), sizes=tuple(int(x) for x in s[a:b])))
        if not last:
            det.boundaries.append(Boundary(lo=cap_ci[0], hi=cap_ci[1],
                                           capacity=cap))
    return det


def detect_from_result(res, mix: str | None = None, **kw) -> Detection:
    """Run detection over one mix's points of a BenchResult (duck-typed:
    anything with ``.points`` carrying ``.mix``/``.nbytes``/``.gbps``)."""
    mixes = []
    for p in res.points:
        if p.mix not in mixes:
            mixes.append(p.mix)
    if mix is None:
        if not mixes:
            raise ValueError("result has no points")
        mix = mixes[0]
    pts = {}
    for p in res.points:
        if p.mix == mix:
            pts.setdefault(p.nbytes, []).append(p.gbps)
    if not pts:
        raise ValueError(f"no points for mix {mix!r} (have: {mixes})")
    sizes = sorted(pts)
    gbps = [float(np.mean(pts[s])) for s in sizes]
    return detect_levels(sizes, gbps, mix=mix, **kw)
