"""repro.characterize — measurement-driven machine characterization.

Turns raw ``repro.bench`` results into a fitted machine model, the way the
paper turns its sweeps into §5-§6 conclusions:

    from repro.characterize import characterize, render_markdown
    model, sweep = characterize()         # adaptive sweep + detection + fit
    print(render_markdown(model, sweep))
    model.to_json("fitted_machine_model.json")

Layers (measurement -> inference):

* ``adaptive``  — boundary-bisecting refinement driver over ``bench.Runner``
  (the paper's fine spatial granularity at a fraction of a dense grid)
* ``detect``    — change-point/plateau detection: levels, capacities and
  bandwidths *with confidence intervals*, no sysfs/documentation input
* ``loaded``    — loaded-latency (Mess-style bandwidth–latency) sweeps over
  the ``latency_chase`` mix's ``load`` axis + per-level knee fits
* ``fit``       — schema-versioned ``FittedMachineModel``; registers into
  the ``core.machine_model`` spec registry; consumed by ``roofline.analyze``
  and ``core.autotune``; ``compare_to`` reproduces the Table-1 deltas
* ``report``    — markdown/JSON rendering (also:
  ``python -m repro.bench characterize``)

Observability: adaptive rounds trace as ``characterize.round`` spans with
``characterize.bisect`` decision events (``--trace``; see
``bench/README.md`` -> Observability), every CLI characterization appends
its bandwidth cells to the run ledger, and the ledger's regression gate
(``python -m repro.bench diff``) reuses ``detect.significant_step`` — the
same noise-aware two-sample threshold the plateau merge applies here.
"""
from repro.characterize.adaptive import (AdaptiveSweep,  # noqa: F401
                                         DEFAULT_RESOLUTION, adaptive_sweep)
from repro.characterize.detect import (Boundary, DetectedLevel,  # noqa: F401
                                       Detection, detect_from_result,
                                       detect_levels)
from repro.characterize.fit import (FITTED_SCHEMA_VERSION,  # noqa: F401
                                    FittedMachineModel, LevelFit,
                                    characterize, crosscheck_prior,
                                    fit_from_result, probe_sizes)
from repro.characterize.loaded import (fit_knee, fit_loaded,  # noqa: F401
                                       loaded_latency_sweep)
from repro.characterize.report import (render_json,  # noqa: F401
                                       render_markdown, write_report)

__all__ = [
    "AdaptiveSweep", "DEFAULT_RESOLUTION", "adaptive_sweep",
    "Boundary", "DetectedLevel", "Detection", "detect_from_result",
    "detect_levels",
    "FITTED_SCHEMA_VERSION", "FittedMachineModel", "LevelFit",
    "characterize", "crosscheck_prior", "fit_from_result", "probe_sizes",
    "fit_knee", "fit_loaded", "loaded_latency_sweep",
    "render_json", "render_markdown", "write_report",
]
