"""Multi-device scaling curves — the paper's Figure 4 (HBM2 scaling vs cores).

Shards a working set over the first k devices and measures aggregate load
throughput; on hardware this reproduces the CMG-saturation study (6 cores
saturate one HBM2 stack), here it validates the harness on host devices.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import buffers, timing
from repro.core.instruction_mix import run_mix


@dataclass
class ScalingPoint:
    devices: int
    mix: str
    nbytes_total: int
    mean_s: float
    gbps: float
    speedup: float = 1.0


def scaling_curve(nbytes_per_device: int, mix: str = "load_sum",
                  device_counts=None, passes: int = 8, reps: int = 8):
    devs = jax.devices()
    device_counts = device_counts or [d for d in (1, 2, 4, 8, 16, 32, 64)
                                      if d <= len(devs)]
    import numpy as np
    points = []
    base = None
    for k in device_counts:
        mesh = Mesh(np.array(devs[:k]).reshape(k), ("d",))
        x = buffers.working_set(nbytes_per_device * k)
        x = jax.device_put(x, NamedSharding(mesh, P("d", None)))

        def fn(x):
            def body(v):  # v: (1, rows_local, 128) per device
                return run_mix(mix, v[0], passes).reshape(1)
            return jax.shard_map(body, mesh=mesh, in_specs=P("d", None, None),
                                 out_specs=P("d"), check_vma=False)(
                x.reshape(k, -1, x.shape[-1])).sum()

        t = timing.time_fn(jax.jit(fn), x, reps=reps, warmup=2,
                           bytes_per_call=float(x.size * x.dtype.itemsize) * passes)
        gbps = t.gbps
        if base is None:
            base = gbps
        points.append(ScalingPoint(devices=k, mix=mix,
                                   nbytes_total=x.size * x.dtype.itemsize,
                                   mean_s=t.mean_s, gbps=gbps,
                                   speedup=gbps / base))
    return points
