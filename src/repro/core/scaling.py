"""Legacy multi-device scaling API — now a thin wrapper over ``repro.bench``.

The paper's Figure 4 (HBM2 scaling vs cores) is served by the ``sharded``
backend: ``BenchSpec(backend="sharded", devices=k)`` places the working set
across the first k devices of a 1-D mesh and runs the shared mix registry's
kernels per shard.  ``scaling_curve`` remains for existing callers but owns
no measurement loop — it declares one BenchSpec per device count and lets
the Runner execute them through ``run_many``.
New code should use ``repro.bench`` directly; BenchResult carries the
``devices`` knob per point plus schema/machine metadata this legacy view
lacks.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class ScalingPoint:
    devices: int
    mix: str
    nbytes_total: int
    mean_s: float
    gbps: float
    speedup: float = 1.0


def scaling_curve(nbytes_per_device: int, mix: str = "load_sum",
                  device_counts=None, passes: int = 8, reps: int = 8,
                  backend: str = "sharded"):
    """Weak-scaling sweep: ``nbytes_per_device * k`` total bytes on k devices,
    speedup relative to the first device count measured.  ``backend`` may be
    ``"distributed"`` inside an initialized multi-process run (the counts
    then span *global* devices and must cover every process; timings are
    gathered so the curve is identical on all processes)."""
    import jax

    from repro.bench import BenchSpec, Runner
    from repro.bench import distributed as dist
    if device_counts is None:
        device_counts = (dist.covering_device_counts()
                         if backend == "distributed" else
                         [d for d in dist.DEVICE_LADDER
                          if d <= jax.device_count()])
    specs = [BenchSpec(mixes=(mix,), sizes=(nbytes_per_device * k,),
                       backend=backend, devices=k, passes=passes,
                       reps=reps, warmup=2)
             for k in device_counts]
    res = dist.gather_result(Runner().run_many(specs))
    return [ScalingPoint(devices=p.devices, mix=p.mix, nbytes_total=p.nbytes,
                         mean_s=p.mean_s, gbps=p.gbps, speedup=rel)
            for p, rel in res.baseline_relative(group_key=lambda p: p.mix)]
