"""Serialized timing harness — the CNTVCT + DSB SY/ISB discipline, JAX-side.

The paper reads the generic timer with data/instruction barriers and reports the
cumulative mean over one hundred internal repetitions (§4/§5).  Here:
serialization = ``block_until_ready`` on the kernel output (nothing retires
until all device work is visible); repetition = ``reps`` timed calls after
``warmup`` untimed ones; the report carries the running cumulative mean and the
standard deviation (the paper reports σ for every plot).

Dispatch overhead (~10 us) would swamp cache-resident workloads, so kernels
take an *internal pass count*: they loop over the buffer inside one compiled
call (see instruction_mix.py) exactly like membench's measurement loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class TimingResult:
    times_s: list[float]
    bytes_per_call: float = 0.0
    flops_per_call: float = 0.0

    @property
    def mean_s(self) -> float:
        return float(np.mean(self.times_s))

    @property
    def std_s(self) -> float:
        return float(np.std(self.times_s))

    @property
    def min_s(self) -> float:
        return float(np.min(self.times_s))

    @property
    def cumulative_mean_s(self) -> list[float]:
        c = np.cumsum(self.times_s) / np.arange(1, len(self.times_s) + 1)
        return [float(x) for x in c]

    @property
    def gbps(self) -> float:
        return self.bytes_per_call / self.mean_s / 1e9 if self.mean_s else 0.0

    @property
    def gflops(self) -> float:
        return self.flops_per_call / self.mean_s / 1e9 if self.mean_s else 0.0

    def samples(self, limit: int | None = None) -> tuple[float, ...]:
        """The raw per-rep timings (last ``limit`` when bounded) — what the
        result schema retains per point (``BenchPoint.rep_times_s``) so a
        downstream consumer (the run ledger's noise test) can compute CIs
        instead of trusting the mean triple.  The public (mean, std, min)
        triple is untouched: it is still computed over ALL reps."""
        times = self.times_s if limit is None else self.times_s[-limit:]
        return tuple(float(t) for t in times)

    def summary(self) -> dict:
        return {"mean_s": self.mean_s, "std_s": self.std_s, "min_s": self.min_s,
                "reps": len(self.times_s), "gbps": self.gbps,
                "gflops": self.gflops,
                "rel_std": self.std_s / self.mean_s if self.mean_s else 0.0}


def time_fn(fn, *args, reps: int = 20, warmup: int = 3,
            bytes_per_call: float = 0.0, flops_per_call: float = 0.0
            ) -> TimingResult:
    """Time ``fn(*args)``; fn must return a jax array (serialization point)."""
    import jax
    # BenchSpec validates these for Runner callers; direct callers (legacy
    # sweep/autotune paths, notebooks) used to sail through to np.mean([]) —
    # a RuntimeWarning and a NaN TimingResult instead of an error
    if reps < 1:
        raise ValueError(f"reps must be >= 1: {reps}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0: {warmup}")
    from repro.obs import trace
    tr = trace.get_tracer()
    if tr.enabled:
        # traced path: one span around the warmup block (first call holds
        # lower+compile), one per timed rep.  A separate branch, not a
        # conditional inside the loop: the disabled path below is the
        # byte-identical original loop, so tracing OFF adds zero overhead
        # to the timed reps (guarded by a no-op benchmark test).
        with tr.span("timing.warmup", cat="timing", reps=warmup):
            if warmup:
                out = fn(*args)
                for _ in range(warmup - 1):
                    out = fn(*args)
                jax.block_until_ready(out)
        times = []
        for i in range(reps):
            with tr.span("timing.rep", cat="timing", rep=i):
                t0 = time.perf_counter_ns()
                out = fn(*args)
                jax.block_until_ready(out)
                times.append((time.perf_counter_ns() - t0) / 1e9)
        return TimingResult(times, bytes_per_call, flops_per_call)
    if warmup:                 # warmup=0 is valid: first timed rep compiles
        out = fn(*args)
        for _ in range(warmup - 1):
            out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        out = fn(*args)
        jax.block_until_ready(out)        # the DSB SY / ISB analogue
        times.append((time.perf_counter_ns() - t0) / 1e9)
    return TimingResult(times, bytes_per_call, flops_per_call)
