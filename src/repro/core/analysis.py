"""Sweep analysis — the reasoning in the paper's §6, automated.

* level attribution: mean throughput inside each hierarchy level's working-set
  band (paper: 'cumulative mean over one hundred repetitions' per level)
* mix penalty: throughput of each mix relative to the best at that level — the
  FADD-vs-LOAD-vs-NOP gap that exposes front-end/issue bottlenecks (§6.1-6.3)
* knee/ridge detection: the smallest fma depth k where throughput drops below
  90% of the pure-load mix — the measured ridge point of the machine
"""
from __future__ import annotations

from typing import Union

import numpy as np

from repro.bench.result import BenchResult, level_band  # noqa: F401  (band
#   formula lives with the summarize view; re-exported here for legacy users)
from repro.core.machine_model import HardwareSpec, MachineModel
from repro.core.sweep import SweepResult

#: Both result schemas expose .points (.mix/.nbytes/.gbps), .by_mix and .meta;
#: BenchResult is the versioned schema, SweepResult the legacy one.
Result = Union[BenchResult, SweepResult]


def attribute_levels(res: Result, hw: HardwareSpec) -> dict:
    """level -> {mix: mean GB/s within the level's band}.

    Thin view over ``BenchResult.summarize`` (where the banding now lives —
    figure scripts call it directly); duck-typed so the legacy SweepResult
    works too, since summarize only reads ``.points``.
    """
    summary = BenchResult.summarize(res, levels=hw.levels)
    return {lvl: {m: c["gbps"] for m, c in mixes.items()}
            for lvl, mixes in summary.items()}


def mix_penalties(level_bw: dict) -> dict:
    """Per level: each mix's throughput relative to the best mix — the paper's
    instruction-mix gap (e.g. A64FX L1d: FADD 69% vs LOAD 99%)."""
    out = {}
    for lvl, mixes in level_bw.items():
        best = max(mixes.values())
        out[lvl] = {m: v / best for m, v in mixes.items()}
    return out


def ridge_depth(res: Result, band: tuple[float, float],
                threshold: float = 0.9) -> int | None:
    """Smallest fma-chain depth whose throughput < threshold x load_sum —
    the measured compute/bandwidth crossover inside the given size band."""
    lo, hi = band

    def mean_bw(mix):
        pts = [p.gbps for p in res.by_mix(mix) if lo <= p.nbytes <= hi]
        return float(np.mean(pts)) if pts else None

    base = mean_bw("load_sum")
    if not base:
        return None
    depths = sorted(int(p.mix.split("_")[1]) for p in res.points
                    if p.mix.startswith("fma_"))
    for k in depths:
        bw = mean_bw(f"fma_{k}")
        if bw is not None and bw < threshold * base:
            return k
    return None


def build_machine_model(res: Result, hw: HardwareSpec) -> MachineModel:
    """Thin wrapper over ``repro.characterize.fit`` in *documented-banding*
    mode: per-mix bandwidths attributed inside ``hw``'s level bands, ridge
    measured in the innermost band.  For measurement-*detected* topology
    (no ``hw`` input at all), use ``repro.characterize.characterize`` /
    ``fit_from_result`` directly — they return the richer
    ``FittedMachineModel`` this legacy schema downgrades from."""
    from repro.characterize.fit import fit_from_result
    model = fit_from_result(res, hw=hw, name=hw.name).to_machine_model()
    # legacy contract: hardware carries the DOCUMENTED levels verbatim
    # (sizes + documented read_bw), not the measured-bandwidth view
    model.hardware = {"name": hw.name,
                      "levels": tuple((l.name, l.size_bytes, l.read_bw)
                                      for l in hw.levels)}
    return model


def format_table(level_bw: dict, pen: dict) -> str:
    lines = [f"{'level':8s} {'mix':10s} {'GB/s':>10s} {'rel':>6s}"]
    for lvl, mixes in level_bw.items():
        for m, v in sorted(mixes.items()):
            lines.append(f"{lvl:8s} {m:10s} {v:10.2f} {pen[lvl][m]:6.2f}")
    return "\n".join(lines)
