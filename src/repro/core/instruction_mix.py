"""The instruction-mix ladder — C2 of the paper, TPU-native.

Arm-membench measures the same data stream under LOAD-only / LOAD+FADD /
LOAD+NOP mixes; the throughput *gap* between mixes attributes the bottleneck
(load/store units vs front end).  The TPU port sweeps *work per loaded byte*:

    mix            ops/element   Armv8 analogue
    ``load_sum``   1 add         the FADD accumulation loop (loads feeding FADDs)
    ``copy``       1 store       STREAM-copy (write path exercised)
    ``fma_k``      2k flops      FADD loop with k-deep dependent FMA chain —
                                 the NOP-substitution ladder: as k→0 the kernel
                                 degenerates to pure loads, as k grows the VPU
                                 becomes the limiter; the knee is the measured
                                 ridge point
    ``mxu``        2*128 flops   one 128x128 matmul per tile (MXU saturation)

Each kernel loops ``passes`` times over the buffer inside one compiled call
(the paper's measurement loop).  A one-element self-dependent perturbation
defeats XLA's while-loop invariant code motion — without it the compiler hoists
the whole body out of the loop and measures nothing (the rdtsc-serialization
problem in compiler form).

These jnp kernels are the *oracles*; kernels/membench holds the Pallas TPU
embodiment with explicit BlockSpec tiling (including a true ``load_only``,
which XLA-level code cannot express without the load being dead-code).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.mixes import (FMA_DEPTHS, GEN_SWEEPS_PER_PASS,
                               RW_COMBINE_COEF, MixDef)

# legacy alias — the registry's MixDef is attribute-compatible with the old Mix
Mix = MixDef


def mixes(fma_depths=FMA_DEPTHS) -> dict[str, Mix]:
    """Legacy view of the shared registry (repro.bench.mixes), restricted to
    the XLA-runnable mixes, with the fma family restricted to exactly the
    requested chain depths.  Mixes are declared exactly once, there."""
    from repro.bench.mixes import get_mix, registry
    out = {name: m for name, m in registry().items()
           if m.supports("xla") and not name.startswith("fma_")
           and m.rw is None      # parameterized families stay bench-only
           and not m.chase}      # the latency probe is bench-only too
    for k in fma_depths:
        out[f"fma_{k}"] = get_mix(f"fma_{k}")
    return out


def bytes_per_pass(mix: Mix, nbytes: int) -> float:
    return mix.bytes_per_pass(nbytes)


def flops_per_pass(mix: Mix, n_elems: int) -> float:
    return mix.flops_per_pass(n_elems)


# ---------------------------------------------------------------------------
# XLA kernels (host-measurable oracles)
# ---------------------------------------------------------------------------

def _perturb(x, acc):
    """One-element self-dependent write: defeats loop-invariant hoisting."""
    eps = (acc * 1e-30).astype(x.dtype).reshape(())
    return x.at[(0,) * x.ndim].add(eps)


def _pass_loop(step, passes: int, unroll: int, init):
    """The measurement pass loop, partially unrolled: ``unroll`` chained
    copies of ``step`` per fori_loop trip (``passes / unroll`` trips).  The
    decode/issue-width probe: fewer loop-control instructions per byte moved,
    identical bytes/flops.  ``unroll=1`` is the plain loop.  ``passes`` must
    be a multiple of ``unroll`` (BenchSpec validates explicit passes; the
    Runner rounds auto-picked passes up).

    This loop is for SCALAR-accumulator mixes only (load_sum / fma / mxu /
    strided / blocked): every sweep's contribution folds into the carried
    accumulator, so no sweep can be narrowed away.  Mixes whose sweeps
    produce array outputs (copy / triad / the rw family) must use
    ``_rotating_pass_loop`` — with this loop, only the LAST unrolled sweep's
    outputs would be loop state and XLA narrows every interior sweep to the
    one element the perturbation chain consumes (the dead-interior-sweep
    bug ``repro.audit`` found; fixture
    ``tests/data/hlo/dead_sweep_xla_copy_u4.txt`` pins the broken shape)."""
    if passes % unroll:
        raise ValueError(
            f"passes={passes} is not a multiple of unroll={unroll}")
    if unroll == 1:
        return jax.lax.fori_loop(0, passes, step, init)

    def body(i, carry):
        for _ in range(unroll):         # chained: the sweeps stay ordered
            carry = step(i, carry)
        return carry

    return jax.lax.fori_loop(0, passes // unroll, body, init)


def _rotating_pass_loop(sweep, passes: int, unroll: int, state, out0):
    """The measurement pass loop for mixes whose sweeps produce ARRAY
    outputs (copy / triad / the rw_RtoW family), with rotating output
    buffers: the carry holds one output slot per unrolled sweep, sweep ``j``
    of a trip writes slot ``j``, so EVERY sweep's full output is while-loop
    state.  Loop state must be materialized at each iteration boundary, so
    XLA cannot narrow an interior sweep down to the one element the
    perturbation chain consumes — ``unroll=u`` really moves u sweeps' worth
    of traffic per trip (enforced by ``repro.audit``; rotating-carry
    lowering shape documented in audit/README.md).

    ``sweep(i, state, out) -> (state, out_new)``: ``out`` is the most
    recently produced output (the previous sweep's slot, wrapping to the
    last slot of the previous trip), which is how self-dependent mixes like
    triad chain trips.  Callers must CONSUME every returned slot (read at
    least one element of each after the loop) or XLA's while-loop
    simplifier is free to drop dead slots from the loop state, resurrecting
    the bug this loop exists to fix.

    ``unroll=1`` degenerates to the plain carried loop (one slot — exactly
    the pre-rotation lowering).  ``passes`` must be a multiple of
    ``unroll``, as in ``_pass_loop``.
    """
    if passes % unroll:
        raise ValueError(
            f"passes={passes} is not a multiple of unroll={unroll}")

    def body(i, carry):
        state, slots = carry
        out = slots[-1]                 # the rotation point: newest slot
        new = []
        for _ in range(unroll):         # chained via state AND out
            state, out = sweep(i, state, out)
            new.append(out)
        return (state, tuple(new))

    return jax.lax.fori_loop(0, passes // unroll, body,
                             (state, (out0,) * unroll))


def _consume_slots(acc, slots):
    """Fold one element of every rotating output slot into ``acc`` — the
    post-loop consumption that keeps each slot live loop state."""
    for out in slots:
        for o in jax.tree_util.tree_leaves(out):
            acc = acc + o.reshape(-1)[-1].astype(jnp.float32)
    return acc


def _row_chunks(x, interleave: int):
    """Split rows into ``interleave`` equal chunks — one independent
    dependence chain each.  Data-dependent divisibility surfaces here."""
    rows = x.shape[0]
    if rows % interleave:
        raise ValueError(
            f"interleave={interleave} does not divide {rows} rows")
    return x.reshape(interleave, rows // interleave, *x.shape[1:])


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_load_sum(x, passes: int, unroll: int = 1):
    def body(_, carry):
        x, acc = carry
        acc = acc + jnp.sum(x, dtype=jnp.float32)
        return (_perturb(x, acc), acc)
    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes", "unroll", "interleave"))
def k_load_sum_istream(x, passes: int, unroll: int = 1, interleave: int = 2):
    """load_sum with ``interleave`` independent accumulator chains, one per
    row chunk, combined only after the sweep — same bytes and (to within the
    final combine) the same flops as k_load_sum, but the dependence critical
    path is the chunk reduction, not the whole-buffer reduction."""
    def body(_, carry):
        x, acc = carry
        xs = _row_chunks(x, interleave)
        parts = [jnp.sum(xs[j], dtype=jnp.float32)
                 for j in range(interleave)]    # independent chains
        s = parts[0]
        for p in parts[1:]:                     # combined after the sweep
            s = s + p
        acc = acc + s
        return (_perturb(x, acc), acc)
    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_copy(x, passes: int, unroll: int = 1):
    def sweep(i, carry, _y):
        x, acc = carry
        scale = (1.0 + acc * 0e0).astype(x.dtype)   # forces y to depend on acc
        y = x * scale
        acc = acc + y.reshape(-1)[0].astype(jnp.float32)
        return (x, acc), y
    (_, acc), ys = _rotating_pass_loop(sweep, passes, unroll,
                                       (x, jnp.float32(0)), jnp.zeros_like(x))
    return _consume_slots(acc, ys)


@partial(jax.jit, static_argnames=("passes", "unroll", "interleave"))
def k_copy_istream(x, passes: int, unroll: int = 1, interleave: int = 2):
    """copy with the store stream split into ``interleave`` independent
    per-chunk streams (same bytes; the chunk stores carry no cross-chunk
    dependence)."""
    def sweep(i, carry, _y):
        x, acc = carry
        scale = (1.0 + acc * 0e0).astype(x.dtype)
        xs = _row_chunks(x, interleave)
        y = jnp.concatenate([xs[j] * scale for j in range(interleave)],
                            axis=0)
        acc = acc + y.reshape(-1)[0].astype(jnp.float32)
        return (x, acc), y
    (_, acc), ys = _rotating_pass_loop(sweep, passes, unroll,
                                       (x, jnp.float32(0)), jnp.zeros_like(x))
    return _consume_slots(acc, ys)


@partial(jax.jit, static_argnames=("passes", "depth", "unroll"))
def k_fma(x, passes: int, depth: int, unroll: int = 1):
    def body(_, carry):
        x, acc = carry
        v = x.astype(jnp.float32)
        a = jnp.float32(1.0000001)
        b = jnp.float32(1e-9)
        for _ in range(depth):          # dependent FMA chain per element
            v = v * a + b
        acc = acc + jnp.sum(v)
        return (_perturb(x, acc), acc)
    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_mxu(x, w, passes: int, unroll: int = 1):
    """x: (rows, 128); w: (128, 128) — one matmul per pass (MXU analogue)."""
    def body(_, carry):
        x, acc = carry
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        acc = acc + jnp.sum(y[:1, :1])
        return (_perturb(x, acc), acc)
    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("streams", "passes", "unroll"))
def k_strided_sum(x, streams: int, passes: int, unroll: int = 1):
    """load_sum over S interleaved strided address streams (C3 — the paper's
    multi-pointer addressing study; stride defeats the linear prefetcher)."""
    def body(_, carry):
        x, acc = carry
        s = jnp.float32(0)
        for k in range(streams):               # S interleaved address streams
            s = s + jnp.sum(x[k::streams], dtype=jnp.float32)
        eps = (s * 1e-30).astype(x.dtype).reshape(())
        return (x.at[0, 0].add(eps), acc + s)
    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("rows", "passes", "unroll"))
def k_blocked_sum(x, rows: int, passes: int, unroll: int = 1):
    """load_sum walking the buffer in (rows, lanes) blocks (C4 — the
    LD1D/LD2D/LD4D registers-per-load analogue)."""
    n_blocks = x.shape[0] // rows

    def body(_, carry):
        x, acc = carry

        def inner(i, a):
            blk = jax.lax.dynamic_slice_in_dim(x, i * rows, rows, axis=0)
            return a + jnp.sum(blk, dtype=jnp.float32)

        s = jax.lax.fori_loop(0, n_blocks, inner, jnp.float32(0))
        eps = (s * 1e-30).astype(x.dtype).reshape(())
        return (x.at[0, 0].add(eps), acc + s)

    _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_rw(streams, outs, passes: int, unroll: int = 1):
    """The R:W ratio family: R read streams combined triad-style, the result
    stored to W write streams (paper: store-path attribution — the relation
    between loads and stores, not raw volume, sets the rate).

    streams: tuple of R same-shape read buffers; outs: tuple of W write
    buffers carried through the pass loop (each pass stores all W) — their
    initial values are never read, only their shape/dtype, so callers may
    alias one buffer for all W seeds.  A
    self-dependence through the accumulator chains the passes (defeats
    loop-invariant hoisting); per-write eps terms keep the W stores distinct.
    rw_1to1 degenerates to ``copy``'s stream pattern, rw_2to1 to ``triad``'s.

    Oracle caveat (the ``load_only`` situation in reverse): at W >= 2, XLA
    is free to duplicate the R-stream combine into each output's fusion, so
    the *real* read traffic can exceed the accounted R streams per pass —
    XLA-level code cannot pin a value to exactly one materialization.  The
    Pallas embodiment (kernels.membench._rw_kernel) has explicit refs and
    moves exactly the accounted (R + W) streams; use it for
    measurement-grade store-path numbers, this oracle for semantics and
    accounting.
    """
    def sweep(_, acc, outs):
        eps = (acc * 1e-30).astype(streams[0].dtype)
        # the coefficient rides on the carried accumulator so the per-stream
        # multiply (and the stream read feeding it) cannot be hoisted out of
        # the while loop as loop-invariant — same discipline as _perturb
        coef = jnp.asarray(RW_COMBINE_COEF, streams[0].dtype) + eps
        v = streams[0] + eps
        for s in streams[1:]:
            v = v + coef * s
        outs = tuple(v + jnp.asarray(w, v.dtype) * eps
                     for w in range(len(outs)))
        return acc + v.reshape(-1)[0].astype(jnp.float32), outs
    acc, slots = _rotating_pass_loop(sweep, passes, unroll,
                                     jnp.float32(0), outs)
    return _consume_slots(acc, slots)


@partial(jax.jit, static_argnames=("passes", "unroll", "interleave"))
def k_rw_istream(streams, outs, passes: int, unroll: int = 1,
                 interleave: int = 2):
    """k_rw with the R-stream combine split into ``interleave`` independent
    row-chunk folds, concatenated before the W stores — identical values and
    accounting to k_rw (rw_2to1 at interleave=1 degenerates to it), shorter
    dependence chains per sweep."""
    def sweep(_, acc, outs):
        eps = (acc * 1e-30).astype(streams[0].dtype)
        coef = jnp.asarray(RW_COMBINE_COEF, streams[0].dtype) + eps
        chunked = [_row_chunks(s, interleave) for s in streams]
        vs = []
        for j in range(interleave):             # independent fold chains
            v = chunked[0][j] + eps
            for s in chunked[1:]:
                v = v + coef * s[j]
            vs.append(v)
        v = jnp.concatenate(vs, axis=0)         # combined before the stores
        outs = tuple(v + jnp.asarray(w, v.dtype) * eps
                     for w in range(len(outs)))
        return acc + v.reshape(-1)[0].astype(jnp.float32), outs
    acc, slots = _rotating_pass_loop(sweep, passes, unroll,
                                     jnp.float32(0), outs)
    return _consume_slots(acc, slots)


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_triad(a, b, c, passes: int, unroll: int = 1):
    """STREAM triad a = b + s*c with a self-dependence chaining the passes
    (the rotating ``out`` slot IS the self-dependent a stream)."""
    def sweep(_, acc, a):
        a = b + 1.5 * c + a * 1e-30          # triad with self-dependence
        return acc + a[0, 0].astype(jnp.float32), a
    acc, slots = _rotating_pass_loop(sweep, passes, unroll,
                                     jnp.float32(0), a)
    return _consume_slots(acc, slots)


@lru_cache(maxsize=64)
def _chase_perm_np(rows: int, lanes: int, parts: int):
    if parts < 1 or rows % parts:
        raise ValueError(
            f"chase_perm: parts={parts} must divide rows={rows} (each part "
            f"is a row-contiguous segment with its own pointer cycle)")
    n = rows * lanes
    m = n // parts
    rng = np.random.default_rng(0)          # deterministic walk order
    out = np.empty(n, dtype=np.int32)
    for s in range(parts):
        order = rng.permutation(m)
        seg = np.empty(m, dtype=np.int32)
        seg[order] = np.roll(order, -1)     # order[i] -> order[i+1]: 1 cycle
        out[s * m:(s + 1) * m] = seg
    return out.reshape(rows, lanes)


def chase_perm(shape, parts: int = 1):
    """The pointer-chase buffer for ``latency_chase``: an int32 (rows, lanes)
    array whose flat view is split into ``parts`` row-contiguous segments,
    each holding one full permutation cycle of PART-LOCAL flat indices
    0..m-1 (``flat[j]`` is the successor of ``j``).  Local indices make the
    same buffer correct under mesh row-sharding (``parts=devices``: every
    shard walks its own cycle) and Pallas row-tiling
    (``parts=rows/block_rows``: every tile walks its own cycle).  Cycles are
    a seeded random shuffle, so consecutive steps have no address locality a
    prefetcher could exploit.  Cached; returns numpy (callers place it)."""
    rows, lanes = shape
    return _chase_perm_np(int(rows), int(lanes), int(parts))


@partial(jax.jit, static_argnames=("passes", "unroll"))
def k_chase(perm, passes: int, unroll: int = 1):
    """The latency probe: one pass = n dependent loads ``j = flat[j]``
    walking the full permutation cycle.  Every load's address is the
    previous load's value, so loads cannot overlap, be batched, or be
    hoisted — wall time per step is access latency by construction (the
    audit's DCE/liveness check verifies the chain stays live; no waiver)."""
    flat = perm.reshape(-1)
    n = flat.shape[0]

    def walk(j):
        return jax.lax.fori_loop(0, n, lambda _, jj: flat[jj], j)

    def body(_, carry):
        j, acc = carry
        j = walk(j)
        return (j, acc + j.astype(jnp.float32))

    j, acc = _pass_loop(body, passes, unroll,
                        (jnp.int32(0), jnp.float32(0)))
    return acc + j.astype(jnp.float32)


@partial(jax.jit, static_argnames=("passes", "unroll", "load"))
def k_chase_loaded(perm, gen, passes: int, unroll: int = 1, load: int = 1):
    """The single-device loaded-latency composite: the chase walk of
    ``k_chase`` co-scheduled with ``load`` bandwidth generators, each
    performing ``GEN_SWEEPS_PER_PASS`` load_sum sweeps of ``gen`` per probe
    pass (a Mess generator runs for the probe's *duration*; on a serialized
    substrate that is emulated by this fixed generator:probe work ratio).
    Generator sweeps chain through the accumulator via ``_perturb`` — the
    same anti-hoisting discipline as ``k_load_sum`` — so declared generator
    traffic is what executes."""
    flat = perm.reshape(-1)
    n = flat.shape[0]

    def walk(j):
        return jax.lax.fori_loop(0, n, lambda _, jj: flat[jj], j)

    def gsweep(_, c):
        g, a = c
        a = a + jnp.sum(g, dtype=jnp.float32)
        return (_perturb(g, a), a)

    def body(_, carry):
        gen, j, acc = carry
        j = walk(j)
        gen, acc = jax.lax.fori_loop(0, load * GEN_SWEEPS_PER_PASS, gsweep,
                                     (gen, acc + j.astype(jnp.float32)))
        return (gen, j, acc)

    _, j, acc = _pass_loop(body, passes, unroll,
                           (gen, jnp.int32(0), jnp.float32(0)))
    return acc + j.astype(jnp.float32)


def run_mix(mix_name: str, x, passes: int, w=None, unroll: int = 1,
            interleave: int = 1):
    if interleave > 1:
        # only the mixes with an interleaved variant (independent per-chunk
        # dependence chains); the bench backends gate this before timing
        if mix_name == "load_sum":
            return k_load_sum_istream(x, passes, unroll, interleave)
        if mix_name == "copy":
            return k_copy_istream(x, passes, unroll, interleave)
        if mix_name.startswith("rw_"):
            from repro.bench.mixes import get_mix
            reads, writes = get_mix(mix_name).rw
            return k_rw_istream(rw_streams(x, reads), (x,) * writes, passes,
                                unroll, interleave)
        raise KeyError(
            f"mix {mix_name!r} has no interleaved (interleave > 1) variant; "
            f"interleavable mixes: load_sum, copy, rw_RtoW")
    if mix_name == "load_sum":
        return k_load_sum(x, passes, unroll)
    if mix_name == "copy":
        return k_copy(x, passes, unroll)
    if mix_name == "mxu":
        if w is None:
            w = jnp.eye(x.shape[-1], dtype=x.dtype)
        return k_mxu(x, w, passes, unroll)
    if mix_name == "triad":
        return k_triad(jnp.zeros_like(x), x, x * 0.5, passes, unroll)
    if mix_name == "latency_chase":
        # convenience path: x supplies only the shape — the probe walks a
        # deterministic permutation buffer built here (the bench backends
        # bind the perm outside the timed call)
        return k_chase(jnp.asarray(chase_perm(x.shape)), passes, unroll)
    if mix_name.startswith("fma_"):
        return k_fma(x, passes, int(mix_name.split("_")[1]), unroll)
    if mix_name.startswith("rw_"):
        # convenience path: companions built here, INSIDE any timing — the
        # bench backends bind their own streams outside the timed call
        from repro.bench.mixes import get_mix
        reads, writes = get_mix(mix_name).rw
        return k_rw(rw_streams(x, reads), (x,) * writes, passes, unroll)
    raise KeyError(mix_name)


def rw_streams(x, reads: int) -> tuple:
    """The R read streams of an rw mix: x plus R-1 scaled companions (each a
    distinct buffer, so the kernel really issues R loads per element)."""
    return (x,) + tuple(x * (0.5 ** r) for r in range(1, reads))
