"""The instruction-mix ladder — C2 of the paper, TPU-native.

Arm-membench measures the same data stream under LOAD-only / LOAD+FADD /
LOAD+NOP mixes; the throughput *gap* between mixes attributes the bottleneck
(load/store units vs front end).  The TPU port sweeps *work per loaded byte*:

    mix            ops/element   Armv8 analogue
    ``load_sum``   1 add         the FADD accumulation loop (loads feeding FADDs)
    ``copy``       1 store       STREAM-copy (write path exercised)
    ``fma_k``      2k flops      FADD loop with k-deep dependent FMA chain —
                                 the NOP-substitution ladder: as k→0 the kernel
                                 degenerates to pure loads, as k grows the VPU
                                 becomes the limiter; the knee is the measured
                                 ridge point
    ``mxu``        2*128 flops   one 128x128 matmul per tile (MXU saturation)

Each kernel loops ``passes`` times over the buffer inside one compiled call
(the paper's measurement loop).  A one-element self-dependent perturbation
defeats XLA's while-loop invariant code motion — without it the compiler hoists
the whole body out of the loop and measures nothing (the rdtsc-serialization
problem in compiler form).

These jnp kernels are the *oracles*; kernels/membench holds the Pallas TPU
embodiment with explicit BlockSpec tiling (including a true ``load_only``,
which XLA-level code cannot express without the load being dead-code).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Mix:
    name: str
    flops_per_elem: float     # arithmetic per element per pass
    reads_per_elem: float = 1.0
    writes_per_elem: float = 0.0


def mixes(fma_depths=(1, 2, 4, 8, 16, 32, 64)) -> dict[str, Mix]:
    out = {
        "load_sum": Mix("load_sum", 1.0),
        "copy": Mix("copy", 0.0, reads_per_elem=1.0, writes_per_elem=1.0),
        "mxu": Mix("mxu", 2.0 * 128.0),
    }
    for k in fma_depths:
        out[f"fma_{k}"] = Mix(f"fma_{k}", 2.0 * k)
    return out


def bytes_per_pass(mix: Mix, nbytes: int) -> float:
    return (mix.reads_per_elem + mix.writes_per_elem) * nbytes


def flops_per_pass(mix: Mix, n_elems: int) -> float:
    return mix.flops_per_elem * n_elems


# ---------------------------------------------------------------------------
# XLA kernels (host-measurable oracles)
# ---------------------------------------------------------------------------

def _perturb(x, acc):
    """One-element self-dependent write: defeats loop-invariant hoisting."""
    eps = (acc * 1e-30).astype(x.dtype).reshape(())
    return x.at[(0,) * x.ndim].add(eps)


@partial(jax.jit, static_argnames=("passes",))
def k_load_sum(x, passes: int):
    def body(_, carry):
        x, acc = carry
        acc = acc + jnp.sum(x, dtype=jnp.float32)
        return (_perturb(x, acc), acc)
    _, acc = jax.lax.fori_loop(0, passes, body, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes",))
def k_copy(x, passes: int):
    def body(i, carry):
        x, y, acc = carry
        scale = (1.0 + acc * 0e0).astype(x.dtype)   # forces y to depend on acc
        y = x * scale
        acc = acc + y.reshape(-1)[0].astype(jnp.float32)
        return (x, y, acc)
    x0 = x
    y0 = jnp.zeros_like(x)
    _, y, acc = jax.lax.fori_loop(0, passes, body, (x0, y0, jnp.float32(0)))
    return acc + y.reshape(-1)[-1].astype(jnp.float32)


@partial(jax.jit, static_argnames=("passes", "depth"))
def k_fma(x, passes: int, depth: int):
    def body(_, carry):
        x, acc = carry
        v = x.astype(jnp.float32)
        a = jnp.float32(1.0000001)
        b = jnp.float32(1e-9)
        for _ in range(depth):          # dependent FMA chain per element
            v = v * a + b
        acc = acc + jnp.sum(v)
        return (_perturb(x, acc), acc)
    _, acc = jax.lax.fori_loop(0, passes, body, (x, jnp.float32(0)))
    return acc


@partial(jax.jit, static_argnames=("passes",))
def k_mxu(x, w, passes: int):
    """x: (rows, 128); w: (128, 128) — one matmul per pass (MXU analogue)."""
    def body(_, carry):
        x, acc = carry
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        acc = acc + jnp.sum(y[:1, :1])
        return (_perturb(x, acc), acc)
    _, acc = jax.lax.fori_loop(0, passes, body, (x, jnp.float32(0)))
    return acc


def run_mix(mix_name: str, x, passes: int, w=None):
    if mix_name == "load_sum":
        return k_load_sum(x, passes)
    if mix_name == "copy":
        return k_copy(x, passes)
    if mix_name == "mxu":
        if w is None:
            w = jnp.eye(x.shape[-1], dtype=x.dtype)
        return k_mxu(x, w, passes)
    if mix_name.startswith("fma_"):
        return k_fma(x, passes, int(mix_name.split("_")[1]))
    raise KeyError(mix_name)
