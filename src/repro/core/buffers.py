"""Benchmark buffer initialization — the paper's denormal-avoiding discipline.

x86-membench initializes buffers with a cycle of a user-defined number, its
reciprocal, and the additive inverses of both: (v, 1/v, -v, -1/v).  This
guarantees no denormals (which stall FP pipelines) while keeping non-trivial
data (data values influence power draw and, under power caps, throughput —
paper §2/§3.2).  Kept verbatim here, property-tested in tests/test_core.py.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

DEFAULT_VALUE = 1.234567


def init_pattern(n: int, value: float = DEFAULT_VALUE, dtype=jnp.float32):
    """(v, 1/v, -v, -1/v) cycled to length n."""
    if value == 0 or not np.isfinite(value):
        raise ValueError("init value must be finite and nonzero")
    cycle = np.array([value, 1.0 / value, -value, -1.0 / value], dtype=np.float64)
    buf = np.tile(cycle, n // 4 + 1)[:n]
    arr = jnp.asarray(buf, dtype=dtype)
    return arr


def working_set_shape(nbytes: int, dtype=jnp.float32, lanes: int = 128
                      ) -> tuple[int, int]:
    """The (rows, lanes) shape ``working_set`` would allocate for ~nbytes —
    lets callers plan/validate a sweep without touching device memory."""
    itemsize = jnp.dtype(dtype).itemsize
    rows = max(8, int(round(nbytes / (lanes * itemsize) / 8)) * 8)
    return (rows, lanes)


def working_set(nbytes: int, dtype=jnp.float32, value: float = DEFAULT_VALUE,
                lanes: int = 128):
    """A 2D (rows, lanes) buffer of ~nbytes — 2D so Pallas BlockSpecs tile it
    natively ((8,128)-aligned, the v5e register tile)."""
    rows, lanes = working_set_shape(nbytes, dtype, lanes)
    n = rows * lanes
    if jnp.issubdtype(dtype, jnp.integer):
        cycle = np.array([1, 7, -1, -7], dtype=np.int64)
        buf = np.tile(cycle, n // 4 + 1)[:n].astype(np.dtype(dtype.name
                                                             if hasattr(dtype, "name")
                                                             else dtype))
        return jnp.asarray(buf).reshape(rows, lanes)
    return init_pattern(n, value, dtype).reshape(rows, lanes)


def has_denormals(arr) -> bool:
    a = np.asarray(arr, dtype=np.float64)
    finfo = np.finfo(np.asarray(arr).dtype) if np.asarray(arr).dtype.kind == "f" \
        else None
    if finfo is None:
        return False
    nz = a[a != 0.0]
    return bool(np.any(np.abs(nz) < finfo.tiny))


def sizes_logspace(lo: int, hi: int, per_decade: int = 8) -> list[int]:
    """Log-spaced working-set sizes (bytes), 8-row aligned by working_set()."""
    n = max(2, int(np.ceil((np.log10(hi) - np.log10(lo)) * per_decade)))
    out = np.unique(np.geomspace(lo, hi, n).astype(np.int64))
    return [int(x) for x in out]


# --------------------------------------------------------------------------
# shared sweep grids — ONE grid constructor for the figure scripts and the
# adaptive characterization driver (previously every script carried its own
# size list, and no two agreed on the span)
# --------------------------------------------------------------------------

#: canonical hierarchy span: below the smallest L1d the paper studies up to
#: decisively DRAM-resident on every host we run on
HIERARCHY_SPAN = (16 * 2**10, 128 * 2**20)

#: the fixed quick/smoke ladder: one size per typical level (L1/L2/LLC/DRAM)
QUICK_SIZES = (32 * 2**10, 256 * 2**10, 2 * 2**20, 16 * 2**20)


def snap_sizes(sizes, dtype=jnp.float32, lanes: int = 128) -> list[int]:
    """Requested byte counts -> the *real* working-set sizes
    ``working_set`` would allocate, deduplicated and sorted.  Two requests
    that round to the same (rows, lanes) tile are one measurement — the
    adaptive driver relies on this to avoid re-timing a size it already has
    (and to notice when a bisection bracket is below tile resolution)."""
    itemsize = jnp.dtype(dtype).itemsize
    out = set()
    for s in sizes:
        rows, l = working_set_shape(int(s), dtype, lanes)
        out.add(rows * l * itemsize)
    return sorted(out)


def size_grid(lo: int = HIERARCHY_SPAN[0], hi: int = HIERARCHY_SPAN[1],
              per_decade: int = 6, dtype=jnp.float32) -> list[int]:
    """Log-spaced grid snapped to real working-set sizes (the grid every
    sweep actually measures; ``sizes_logspace`` kept as the raw generator)."""
    return snap_sizes(sizes_logspace(lo, hi, per_decade), dtype=dtype)


def hierarchy_grid(quick: bool = False, lo: int = HIERARCHY_SPAN[0],
                   hi: int = HIERARCHY_SPAN[1], per_decade: int = 6
                   ) -> tuple[int, ...]:
    """The canonical hierarchy-sweep working-set grid (fig scripts, the
    characterize driver's coarse round).  ``quick`` returns the fixed
    one-size-per-level ladder shared by every ``--quick`` mode."""
    if quick:
        return QUICK_SIZES
    return tuple(size_grid(lo, hi, per_decade))
