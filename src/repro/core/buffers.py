"""Benchmark buffer initialization — the paper's denormal-avoiding discipline.

x86-membench initializes buffers with a cycle of a user-defined number, its
reciprocal, and the additive inverses of both: (v, 1/v, -v, -1/v).  This
guarantees no denormals (which stall FP pipelines) while keeping non-trivial
data (data values influence power draw and, under power caps, throughput —
paper §2/§3.2).  Kept verbatim here, property-tested in tests/test_core.py.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

DEFAULT_VALUE = 1.234567


def init_pattern(n: int, value: float = DEFAULT_VALUE, dtype=jnp.float32):
    """(v, 1/v, -v, -1/v) cycled to length n."""
    if value == 0 or not np.isfinite(value):
        raise ValueError("init value must be finite and nonzero")
    cycle = np.array([value, 1.0 / value, -value, -1.0 / value], dtype=np.float64)
    buf = np.tile(cycle, n // 4 + 1)[:n]
    arr = jnp.asarray(buf, dtype=dtype)
    return arr


def working_set_shape(nbytes: int, dtype=jnp.float32, lanes: int = 128
                      ) -> tuple[int, int]:
    """The (rows, lanes) shape ``working_set`` would allocate for ~nbytes —
    lets callers plan/validate a sweep without touching device memory."""
    itemsize = jnp.dtype(dtype).itemsize
    rows = max(8, int(round(nbytes / (lanes * itemsize) / 8)) * 8)
    return (rows, lanes)


def working_set(nbytes: int, dtype=jnp.float32, value: float = DEFAULT_VALUE,
                lanes: int = 128):
    """A 2D (rows, lanes) buffer of ~nbytes — 2D so Pallas BlockSpecs tile it
    natively ((8,128)-aligned, the v5e register tile)."""
    rows, lanes = working_set_shape(nbytes, dtype, lanes)
    n = rows * lanes
    if jnp.issubdtype(dtype, jnp.integer):
        cycle = np.array([1, 7, -1, -7], dtype=np.int64)
        buf = np.tile(cycle, n // 4 + 1)[:n].astype(np.dtype(dtype.name
                                                             if hasattr(dtype, "name")
                                                             else dtype))
        return jnp.asarray(buf).reshape(rows, lanes)
    return init_pattern(n, value, dtype).reshape(rows, lanes)


def has_denormals(arr) -> bool:
    a = np.asarray(arr, dtype=np.float64)
    finfo = np.finfo(np.asarray(arr).dtype) if np.asarray(arr).dtype.kind == "f" \
        else None
    if finfo is None:
        return False
    nz = a[a != 0.0]
    return bool(np.any(np.abs(nz) < finfo.tiny))


def sizes_logspace(lo: int, hi: int, per_decade: int = 8) -> list[int]:
    """Log-spaced working-set sizes (bytes), 8-row aligned by working_set()."""
    n = max(2, int(np.ceil((np.log10(hi) - np.log10(lo)) * per_decade)))
    out = np.unique(np.geomspace(lo, hi, n).astype(np.int64))
    return [int(x) for x in out]
