"""Legacy hierarchy-sweep API — now a thin wrapper over ``repro.bench``.

``run_sweep`` builds a BenchSpec and hands it to the Runner (the repo's one
measurement loop); SweepPoint/SweepResult remain as the pre-``repro.bench``
result schema for existing artifacts and callers.  New code should use
``repro.bench.BenchSpec`` + ``Runner`` directly — BenchResult carries
schema_version, backend, and machine metadata that this legacy schema lacks.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path

import jax.numpy as jnp

from repro.bench.runner import pick_passes  # noqa: F401  (legacy re-export)


@dataclass
class SweepPoint:
    nbytes: int
    mix: str
    dtype: str
    passes: int
    mean_s: float
    std_s: float
    gbps: float
    gflops: float


@dataclass
class SweepResult:
    points: list[SweepPoint] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    def by_mix(self, mix: str) -> list[SweepPoint]:
        return [p for p in self.points if p.mix == mix]

    def to_json(self, path: str | Path):
        Path(path).write_text(json.dumps(
            {"meta": self.meta, "points": [asdict(p) for p in self.points]},
            indent=2))

    @staticmethod
    def from_json(path: str | Path) -> "SweepResult":
        d = json.loads(Path(path).read_text())
        return SweepResult([SweepPoint(**p) for p in d["points"]], d["meta"])

    @staticmethod
    def from_bench(res) -> "SweepResult":
        """Downgrade a repro.bench.BenchResult to the legacy schema."""
        return SweepResult(
            points=[SweepPoint(nbytes=p.nbytes, mix=p.mix, dtype=p.dtype,
                               passes=p.passes, mean_s=p.mean_s, std_s=p.std_s,
                               gbps=p.gbps, gflops=p.gflops)
                    for p in res.points],
            meta=dict(res.meta))


def run_sweep(sizes: list[int] | None = None,
              mix_names: list[str] | None = None,
              dtype=jnp.float32,
              reps: int = 10,
              target_bytes: float = 2e8,
              value: float | None = None) -> SweepResult:
    from repro.bench import BenchSpec, Runner
    from repro.core import buffers
    sizes = sizes or buffers.sizes_logspace(16 * 2**10, 64 * 2**20,
                                            per_decade=6)
    spec = BenchSpec(
        mixes=tuple(mix_names or ("load_sum", "copy", "fma_8")),
        sizes=tuple(sizes), dtype=str(jnp.dtype(dtype)), backend="xla",
        reps=reps, warmup=2, target_bytes=target_bytes,
        value=buffers.DEFAULT_VALUE if value is None else value)
    return SweepResult.from_bench(Runner().run(spec))
