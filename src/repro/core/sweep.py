"""Hierarchy throughput sweep — C1 of the paper.

One run walks working-set sizes across every level of the memory hierarchy
(host: L1d -> L2 -> L3 -> DRAM; TPU target: VMEM -> HBM), measuring each
instruction mix at each size.  This *is* the paper's Figure 2/5/6 engine.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path

import jax.numpy as jnp

from repro.core import buffers, instruction_mix, timing


@dataclass
class SweepPoint:
    nbytes: int
    mix: str
    dtype: str
    passes: int
    mean_s: float
    std_s: float
    gbps: float
    gflops: float


@dataclass
class SweepResult:
    points: list[SweepPoint] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    def by_mix(self, mix: str) -> list[SweepPoint]:
        return [p for p in self.points if p.mix == mix]

    def to_json(self, path: str | Path):
        Path(path).write_text(json.dumps(
            {"meta": self.meta, "points": [asdict(p) for p in self.points]},
            indent=2))

    @staticmethod
    def from_json(path: str | Path) -> "SweepResult":
        d = json.loads(Path(path).read_text())
        return SweepResult([SweepPoint(**p) for p in d["points"]], d["meta"])


def pick_passes(nbytes: int, target_bytes: float = 2e8) -> int:
    """Enough passes that one timed call moves ~target_bytes (>= ms-scale)."""
    return max(1, int(target_bytes / max(nbytes, 1)))


def run_sweep(sizes: list[int] | None = None,
              mix_names: list[str] | None = None,
              dtype=jnp.float32,
              reps: int = 10,
              target_bytes: float = 2e8,
              value: float = buffers.DEFAULT_VALUE) -> SweepResult:
    sizes = sizes or buffers.sizes_logspace(16 * 2**10, 64 * 2**20, per_decade=6)
    all_mixes = instruction_mix.mixes()
    mix_names = mix_names or ["load_sum", "copy", "fma_8"]

    res = SweepResult(meta={"dtype": str(jnp.dtype(dtype)), "reps": reps,
                            "sizes": sizes, "mixes": mix_names})
    for nbytes in sizes:
        x = buffers.working_set(nbytes, dtype=dtype, value=value)
        real_bytes = x.size * x.dtype.itemsize
        passes = pick_passes(real_bytes, target_bytes)
        for name in mix_names:
            mix = all_mixes[name]
            t = timing.time_fn(
                lambda: instruction_mix.run_mix(name, x, passes),
                reps=reps, warmup=2,
                bytes_per_call=instruction_mix.bytes_per_pass(mix, real_bytes) * passes,
                flops_per_call=instruction_mix.flops_per_pass(mix, x.size) * passes)
            res.points.append(SweepPoint(
                nbytes=real_bytes, mix=name, dtype=str(jnp.dtype(dtype)),
                passes=passes, mean_s=t.mean_s, std_s=t.std_s,
                gbps=t.gbps, gflops=t.gflops))
    return res
