"""MachineModel — the paper's Table 1, as a data structure the framework uses.

Holds documented peaks (the paper compares measured vs documented throughout)
and measured sweep results; feeds the roofline analyzer and the kernel
autotuner.  TPU v5e constants come from the assignment; the host entry is
whatever this container measures (the benchmark proves itself on the machine it
runs on, exactly like the paper's three Arm systems).

Conventions:

* ``peak_flops=None`` / ``read_bw=None`` mean *undocumented* (the paper's
  Table 1 leaves several cells blank); ``0.0`` is reserved for a measured
  zero, which never occurs for a documented peak.
* Documented specs live in a name-keyed registry (``register_spec`` /
  ``get_spec``) so measurement-derived models (``repro.characterize``) can
  register alongside the static tables and be looked up by the same name.
* ``MachineModel`` JSON carries ``model_schema_version``; v1 files (written
  before versioning) load unchanged.  ``hardware["levels"]`` is canonicalized
  to tuples-of-tuples on construction, so ``to_json``/``from_json`` round-trip
  to an *equal* object (the old code silently returned lists after a reload).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Optional

MODEL_SCHEMA_VERSION = 2    # 1 = unversioned seed files (list levels, no key)


@dataclass(frozen=True)
class MemLevel:
    name: str
    size_bytes: Optional[int]      # None = unbounded (DRAM/HBM)
    read_bw: Optional[float]       # documented B/s (None if undocumented)


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: Optional[float]    # documented peak FLOP/s; None = undocumented
    levels: tuple[MemLevel, ...]
    link_bw: Optional[float] = None  # interconnect B/s per link
    frequency_hz: Optional[float] = None
    notes: str = ""


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    levels=(
        MemLevel("vmem", 128 * 2**20, None),   # ~128 MiB software-managed
        MemLevel("hbm", 16 * 2**30, 819e9),
    ),
    link_bw=50e9,
    notes="assignment constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI",
)

# The three paper systems, for the Table-1 comparison benchmark.
A64FX = HardwareSpec(
    name="fujitsu-a64fx", peak_flops=3.072e12,
    levels=(MemLevel("L1d", 64 * 2**10, 230.4e9),
            MemLevel("L2", 8 * 2**20, 115.2e9),
            MemLevel("HBM2", 32 * 2**30, 921.6e9 / 48)),
    frequency_hz=1.8e9, notes="paper Table 1 (per-core cache BW, per-socket DRAM)")
ALTRA = HardwareSpec(
    name="ampere-altra-q80-30", peak_flops=None,   # Table 1 leaves it blank
    levels=(MemLevel("L1d", 64 * 2**10, 96e9),
            MemLevel("L2", 1 * 2**20, None),
            MemLevel("L3", 32 * 2**20, None),
            MemLevel("DRAM", 512 * 2**30, 204.8e9 / 80)),
    frequency_hz=3e9, notes="paper Table 1")
THUNDERX2 = HardwareSpec(
    name="marvell-thunderx2", peak_flops=None,     # Table 1 leaves it blank
    levels=(MemLevel("L1d", 32 * 2**10, 64e9),
            MemLevel("L2", 256 * 2**10, None),
            MemLevel("L3", 28 * 2**20, None),
            MemLevel("DRAM", 128 * 2**30, 170.5e9 / 28)),
    frequency_hz=2e9, notes="paper Table 1")


# --------------------------------------------------------------------------
# spec registry — documented tables and measurement-derived models share one
# namespace, so consumers ask for a machine by name and get whichever exists
# --------------------------------------------------------------------------

_SPECS: dict[str, HardwareSpec] = {}


def register_spec(spec: HardwareSpec, overwrite: bool = False) -> HardwareSpec:
    if spec.name in _SPECS and not overwrite:
        raise ValueError(f"spec {spec.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _SPECS[spec.name] = spec
    return spec


def get_spec(name: str) -> HardwareSpec:
    if name == "host":          # always-fresh sysfs probe, never cached
        return detect_host()
    try:
        return _SPECS[name]
    except KeyError:
        raise KeyError(f"unknown machine spec {name!r}; "
                       f"registered: {sorted(_SPECS)} + 'host'") from None


def available_specs() -> list[str]:
    return sorted(_SPECS)


for _spec in (TPU_V5E, A64FX, ALTRA, THUNDERX2):
    register_spec(_spec)


# --------------------------------------------------------------------------
# host topology from sysfs — a PRIOR, not ground truth: repro.characterize
# cross-checks these sizes against measured boundaries (paper: documentation
# and measurement disagree often enough to be worth a column)
# --------------------------------------------------------------------------

_SIZE_RE = re.compile(r"^\s*(\d+)\s*([a-z]?)(?:i?b)?\s*$", re.IGNORECASE)
_SIZE_MULT = {"": 1, "k": 2**10, "m": 2**20, "g": 2**30}


def parse_cache_size(text: str) -> int:
    """'64K' / '64KiB' / '1024 kB' / '8m' / '65536' -> bytes.

    sysfs nominally emits '<n>K' but kernels and vendor drivers have shipped
    lowercase and 'KiB'-suffixed variants; all of them parse here, anything
    else raises ValueError.
    """
    m = _SIZE_RE.match(text)
    if not m:
        raise ValueError(f"unparseable cache size {text!r}")
    mult = _SIZE_MULT.get(m.group(2).lower())
    if mult is None:
        raise ValueError(f"unknown size suffix in {text!r}")
    return int(m.group(1)) * mult


def detect_host(base: str | Path = "/sys/devices/system/cpu/cpu0/cache"
                ) -> HardwareSpec:
    """Best-effort host cache topology from sysfs (sizes only; BW unmeasured
    until the sweep runs — the paper's 'documentation unavailable' case).

    Hardened: size suffixes parse case-insensitively incl. 'KiB' forms,
    duplicate index entries for the same (level, size) collapse to one
    MemLevel (some kernels expose unified caches under several indices), and
    a missing ``/sys`` tree (macOS, stripped containers) degrades to a
    DRAM-only spec instead of raising.  The result is a *prior*:
    ``repro.characterize`` detects the real boundaries from measurement and
    reports where the two disagree.
    """
    levels: list[MemLevel] = []
    seen: set[tuple[str, int]] = set()
    base = Path(base)
    sysfs_found = base.exists()
    if sysfs_found:
        for idx in sorted(base.glob("index*")):
            try:
                lvl = (idx / "level").read_text().strip()
                typ = (idx / "type").read_text().strip().lower()
                nb = parse_cache_size((idx / "size").read_text().strip())
            except (OSError, ValueError):
                continue
            if typ == "instruction":
                continue
            key = (f"L{lvl}", nb)
            if key in seen:     # duplicate index entry for the same cache
                continue
            seen.add(key)
            levels.append(MemLevel(f"L{lvl}", nb, None))
    levels.sort(key=lambda l: (l.size_bytes, l.name))
    levels.append(MemLevel("DRAM", None, None))
    return HardwareSpec(
        name="host-cpu", peak_flops=None, levels=tuple(levels),
        notes="sizes from sysfs (prior only); bandwidths measured by sweep"
              if sysfs_found else
              "sysfs unavailable; topology must come from measurement")


def _canon_levels(levels) -> tuple[tuple, ...]:
    """[(name, size, bw), ...] in any list/tuple nesting -> tuple of tuples."""
    return tuple(tuple(l) for l in levels)


@dataclass
class MachineModel:
    """Measured model of one machine: per-level bandwidth per mix + ridge."""
    hardware: dict
    level_bw: dict = field(default_factory=dict)   # level -> {mix: GB/s}
    ridge_flops_per_byte: Optional[float] = None
    mix_penalty: dict = field(default_factory=dict)  # mix -> relative to best
    model_schema_version: int = MODEL_SCHEMA_VERSION

    def __post_init__(self):
        # canonical levels: a freshly built model and a JSON-reloaded one
        # compare equal (json turns tuples into lists; we turn them back)
        if isinstance(self.hardware, dict) and "levels" in self.hardware:
            self.hardware = {**self.hardware,
                             "levels": _canon_levels(self.hardware["levels"])}

    def to_json(self, path):
        Path(path).write_text(json.dumps(asdict(self), indent=2, default=str))

    @staticmethod
    def from_dict(d: dict) -> "MachineModel":
        d = dict(d)
        ver = d.pop("model_schema_version", 1)   # v1: files without the key
        if ver > MODEL_SCHEMA_VERSION:
            raise ValueError(f"machine-model schema {ver} newer than "
                             f"supported {MODEL_SCHEMA_VERSION}")
        return MachineModel(**d, model_schema_version=ver)

    @staticmethod
    def from_json(path) -> "MachineModel":
        return MachineModel.from_dict(json.loads(Path(path).read_text()))
