"""MachineModel — the paper's Table 1, as a data structure the framework uses.

Holds documented peaks (the paper compares measured vs documented throughout)
and measured sweep results; feeds the roofline analyzer and the kernel
autotuner.  TPU v5e constants come from the assignment; the host entry is
whatever this container measures (the benchmark proves itself on the machine it
runs on, exactly like the paper's three Arm systems).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Optional


@dataclass(frozen=True)
class MemLevel:
    name: str
    size_bytes: Optional[int]      # None = unbounded (DRAM/HBM)
    read_bw: Optional[float]       # documented B/s (None if undocumented)


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float              # documented peak FLOP/s (per chip / core set)
    levels: tuple[MemLevel, ...]
    link_bw: Optional[float] = None  # interconnect B/s per link
    frequency_hz: Optional[float] = None
    notes: str = ""


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    levels=(
        MemLevel("vmem", 128 * 2**20, None),   # ~128 MiB software-managed
        MemLevel("hbm", 16 * 2**30, 819e9),
    ),
    link_bw=50e9,
    notes="assignment constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI",
)

# The three paper systems, for the Table-1 comparison benchmark.
A64FX = HardwareSpec(
    name="fujitsu-a64fx", peak_flops=3.072e12,
    levels=(MemLevel("L1d", 64 * 2**10, 230.4e9),
            MemLevel("L2", 8 * 2**20, 115.2e9),
            MemLevel("HBM2", 32 * 2**30, 921.6e9 / 48)),
    frequency_hz=1.8e9, notes="paper Table 1 (per-core cache BW, per-socket DRAM)")
ALTRA = HardwareSpec(
    name="ampere-altra-q80-30", peak_flops=None or 0.0,
    levels=(MemLevel("L1d", 64 * 2**10, 96e9),
            MemLevel("L2", 1 * 2**20, None),
            MemLevel("L3", 32 * 2**20, None),
            MemLevel("DRAM", 512 * 2**30, 204.8e9 / 80)),
    frequency_hz=3e9, notes="paper Table 1")
THUNDERX2 = HardwareSpec(
    name="marvell-thunderx2", peak_flops=0.0,
    levels=(MemLevel("L1d", 32 * 2**10, 64e9),
            MemLevel("L2", 256 * 2**10, None),
            MemLevel("L3", 28 * 2**20, None),
            MemLevel("DRAM", 128 * 2**30, 170.5e9 / 28)),
    frequency_hz=2e9, notes="paper Table 1")


def detect_host() -> HardwareSpec:
    """Best-effort host cache topology from sysfs (sizes only; BW unmeasured
    until the sweep runs — the paper's 'documentation unavailable' case)."""
    levels = []
    base = Path("/sys/devices/system/cpu/cpu0/cache")
    if base.exists():
        for idx in sorted(base.glob("index*")):
            try:
                lvl = (idx / "level").read_text().strip()
                typ = (idx / "type").read_text().strip()
                size = (idx / "size").read_text().strip()
                if typ == "Instruction":
                    continue
                mult = {"K": 2**10, "M": 2**20}.get(size[-1], 1)
                nb = int(size[:-1]) * mult if size[-1] in "KM" else int(size)
                levels.append(MemLevel(f"L{lvl}", nb, None))
            except (OSError, ValueError):
                continue
    levels.append(MemLevel("DRAM", None, None))
    return HardwareSpec(name="host-cpu", peak_flops=0.0, levels=tuple(levels),
                        notes="sizes from sysfs; bandwidths measured by sweep")


@dataclass
class MachineModel:
    """Measured model of one machine: per-level bandwidth per mix + ridge."""
    hardware: dict
    level_bw: dict = field(default_factory=dict)   # level -> {mix: GB/s}
    ridge_flops_per_byte: Optional[float] = None
    mix_penalty: dict = field(default_factory=dict)  # mix -> relative to best

    def to_json(self, path):
        Path(path).write_text(json.dumps(asdict(self), indent=2, default=str))

    @staticmethod
    def from_json(path) -> "MachineModel":
        return MachineModel(**json.loads(Path(path).read_text()))
