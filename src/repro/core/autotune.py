"""Block-shape autotuner — the paper's LD1D/LD2D/LD4D study (C4) put to work.

Figure 3 shows A64FX peaks at exactly two registers per load instruction; the
TPU analogue is rows-per-DMA (Pallas block shape).  This module sweeps block
shapes with the membench kernel family and returns the best shape for a given
working-set size — the framework's model kernels consult it instead of
hard-coding tiles.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp


# candidate block shapes: (sublane-multiple rows, 128 lanes) — v5e native tile
# is (8, 128) for f32; LD1/2/4 analogue = 8/16/32/... rows per block.
CANDIDATE_ROWS = (8, 16, 32, 64, 128, 256, 512)

# candidate unroll factors — the instruction-stream axis (paper §5: unrolled
# bodies probe decode/issue width the way LD1/2/4 probe the load path)
CANDIDATE_UNROLLS = (1, 2, 4, 8)


@dataclass
class TuneResult:
    nbytes: int
    dtype: str
    mix: str
    best_rows: int
    table: dict  # rows -> GB/s
    best_unroll: int = 1
    unroll_table: dict | None = None    # unroll -> GB/s (at best_rows)
    unroll_audit: dict | None = None    # unroll -> waiver reason or None
    ecm: dict | None = None   # prefilter provenance: predicted / kept / pruned


def sweep_block_shapes(nbytes: int, mix: str = "load_sum", dtype=jnp.float32,
                       reps: int = 8, interpret: bool = True,
                       tune_unroll: bool = False, model=None,
                       ecm_keep: int | None = None,
                       runner=None) -> TuneResult:
    """Run the *Pallas* membench kernels across block shapes via the bench
    Runner (one BenchSpec per candidate row count; C4 of the paper).

    ``tune_unroll=True`` adds the second objective: at the winning block
    shape, sweep the per-pass unroll factor (the instruction-stream knob —
    paper §5's decode-width probe).  The two axes are swept sequentially,
    not as a cross product: block shape sets the memory-path tiling first,
    unroll then packs the issue path at that tiling.  Compiled cases are
    shared through one Runner, so the unroll leg re-times nothing that
    already traced.

    ``model`` + ``ecm_keep``: prune the candidate ladder with the ECM
    analytic predictor (``repro.audit.ecm``) before timing anything — only
    the ``ecm_keep`` candidates with the best predicted throughput get
    timed; the pruned rows and their predictions land in ``TuneResult.ecm``
    so the saving is auditable, never silent.

    interpret=True on CPU (kernel-body semantics validated); on real TPU pass
    interpret=False for wall-clock-meaningful numbers.
    """
    from repro.bench import BenchSpec, Runner
    from repro.core import buffers
    dtype_s = str(jnp.dtype(dtype))
    itemsize = jnp.dtype(dtype).itemsize
    rows_total = buffers.working_set_shape(nbytes, dtype=dtype)[0]
    runner = runner or Runner()
    candidates = tuple(r for r in CANDIDATE_ROWS
                       if r <= rows_total and not rows_total % r)
    ecm_info = None
    if model is not None and ecm_keep:
        from repro.audit.ecm import ecm_filter_rows
        kept, predicted = ecm_filter_rows(nbytes, model, candidates,
                                          keep=ecm_keep, mix=mix,
                                          itemsize=itemsize)
        ecm_info = {"predicted_gbps": predicted, "kept": list(kept),
                    "pruned": [r for r in candidates if r not in kept]}
        candidates = kept
    table = {}
    for rows in candidates:
        spec = BenchSpec(mixes=(mix,), sizes=(nbytes,), dtype=dtype_s,
                         backend="pallas", block_rows=rows, passes=1,
                         reps=reps, warmup=1, interpret=interpret)
        table[rows] = runner.run(spec).points[0].gbps
    best = max(table, key=table.get)
    best_unroll, unroll_table, unroll_audit = 1, None, None
    if tune_unroll:
        # The unroll objective ranks *audited* GB/s: a candidate whose
        # (mix, backend, unroll) combination carries an accounting waiver
        # (``repro.audit.verify.waiver_reason``) is still timed and
        # reported, but never wins — its declared-bytes normalization is
        # not trusted.  Since the rotating-carry fix retired the
        # carried-mix unroll waiver, every candidate here is sound; the
        # gate is the regression guard against that bug's return (pre-fix,
        # unroll=u timed ~1/u of declared traffic and the phantom ~u x
        # GB/s always crowned the largest candidate).
        from repro.audit.verify import waiver_reason
        from repro.bench.mixes import get_mix
        mixdef = get_mix(mix)
        unroll_table, unroll_audit = {}, {}
        for u in CANDIDATE_UNROLLS:
            spec = BenchSpec(mixes=(mix,), sizes=(nbytes,), dtype=dtype_s,
                             backend="pallas", block_rows=best, passes=u,
                             unroll=u, reps=reps, warmup=1,
                             interpret=interpret)
            unroll_table[u] = runner.run(spec).points[0].gbps
            unroll_audit[u] = waiver_reason(mixdef, "pallas", {"unroll": u})
        sound = [u for u in unroll_table if unroll_audit[u] is None]
        best_unroll = max(sound or unroll_table, key=unroll_table.get)
    return TuneResult(nbytes=nbytes, dtype=dtype_s, mix=mix,
                      best_rows=best, table=table,
                      best_unroll=best_unroll, unroll_table=unroll_table,
                      unroll_audit=unroll_audit, ecm=ecm_info)


def _innermost_capacity(model) -> int | None:
    """Innermost-level capacity from any machine-model flavor: a
    ``characterize.FittedMachineModel`` (detected), a ``HardwareSpec``
    (documented table), or a path to a fitted-model JSON."""
    if model is None:
        return None
    if isinstance(model, (str, Path)):
        from repro.characterize.fit import FittedMachineModel
        model = FittedMachineModel.from_json(model)
    cap = getattr(model, "innermost_capacity", None)   # FittedMachineModel
    if cap:
        return int(cap)
    for lvl in getattr(model, "levels", ()):           # HardwareSpec
        size = getattr(lvl, "size_bytes", None)
        if size:
            return int(size)
    return None


def model_block_rows(model, lanes: int = 128, itemsize: int = 4,
                     default: int = 128) -> int:
    """Largest candidate row count whose block fits in HALF the machine's
    innermost level (detected by ``repro.characterize`` or documented) —
    half, so the block plus its accumulator/companion stream stay resident.
    """
    cap = _innermost_capacity(model)
    if not cap:
        return default
    fitting = [r for r in CANDIDATE_ROWS if r * lanes * itemsize <= cap / 2]
    return max(fitting, default=CANDIDATE_ROWS[0])


def choose_block_rows(nbytes: int, cache_path: str | Path | None = None,
                      default: int = 128, model=None) -> int:
    """Consult a cached tune result; else size blocks against a machine
    model's measured innermost capacity (``model``: FittedMachineModel,
    HardwareSpec, or fitted-model JSON path); else the v5e default."""
    if cache_path and Path(cache_path).exists():
        d = json.loads(Path(cache_path).read_text())
        return int(d.get("best_rows", default))
    if model is not None:
        return model_block_rows(model, default=default)
    return default


def choose_unroll(cache_path: str | Path | None = None,
                  default: int = 1) -> int:
    """The unroll companion to ``choose_block_rows``: consult a cached
    ``sweep_block_shapes(tune_unroll=True)`` result, else the no-unroll
    default (there is no model-derived fallback — issue width is fitted by
    ``repro.istream``, not documented in the spec tables)."""
    if cache_path and Path(cache_path).exists():
        d = json.loads(Path(cache_path).read_text())
        return int(d.get("best_unroll", default))
    return default
