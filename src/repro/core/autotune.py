"""Block-shape autotuner — the paper's LD1D/LD2D/LD4D study (C4) put to work.

Figure 3 shows A64FX peaks at exactly two registers per load instruction; the
TPU analogue is rows-per-DMA (Pallas block shape).  This module sweeps block
shapes with the membench kernel family and returns the best shape for a given
working-set size — the framework's model kernels consult it instead of
hard-coding tiles.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp


# candidate block shapes: (sublane-multiple rows, 128 lanes) — v5e native tile
# is (8, 128) for f32; LD1/2/4 analogue = 8/16/32/... rows per block.
CANDIDATE_ROWS = (8, 16, 32, 64, 128, 256, 512)


@dataclass
class TuneResult:
    nbytes: int
    dtype: str
    mix: str
    best_rows: int
    table: dict  # rows -> GB/s


def sweep_block_shapes(nbytes: int, mix: str = "load_sum", dtype=jnp.float32,
                       reps: int = 8, interpret: bool = True) -> TuneResult:
    """Run the *Pallas* membench kernels across block shapes via the bench
    Runner (one BenchSpec per candidate row count; C4 of the paper).

    interpret=True on CPU (kernel-body semantics validated); on real TPU pass
    interpret=False for wall-clock-meaningful numbers.
    """
    from repro.bench import BenchSpec, Runner
    from repro.core import buffers
    dtype_s = str(jnp.dtype(dtype))
    rows_total = buffers.working_set_shape(nbytes, dtype=dtype)[0]
    runner = Runner()
    table = {}
    for rows in CANDIDATE_ROWS:
        if rows > rows_total or rows_total % rows:
            continue
        spec = BenchSpec(mixes=(mix,), sizes=(nbytes,), dtype=dtype_s,
                         backend="pallas", block_rows=rows, passes=1,
                         reps=reps, warmup=1, interpret=interpret)
        table[rows] = runner.run(spec).points[0].gbps
    best = max(table, key=table.get)
    return TuneResult(nbytes=nbytes, dtype=dtype_s, mix=mix,
                      best_rows=best, table=table)


def choose_block_rows(nbytes: int, cache_path: str | Path | None = None,
                      default: int = 128) -> int:
    """Consult a cached tune result; fall back to the v5e-sensible default."""
    if cache_path and Path(cache_path).exists():
        d = json.loads(Path(cache_path).read_text())
        return int(d.get("best_rows", default))
    return default
