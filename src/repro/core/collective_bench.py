"""Collective / interconnect throughput — C6's remote-access study, mesh-native.

The paper measures NUMA-remote access and multi-core scaling; the TPU analogue
is per-link ICI throughput under each collective pattern.  Runs on any mesh
(host CPU devices for harness validation; real ICI on hardware).  Reports
algorithm bandwidth *and* ring-model link bandwidth so results compare directly
against the documented ~50 GB/s/link.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import buffers, timing


@dataclass
class CollectiveResult:
    op: str
    axis: str
    group_size: int
    nbytes: int
    mean_s: float
    std_s: float
    algo_gbps: float       # payload bytes / time
    link_gbps: float       # ring-model per-link wire bandwidth


def _ring_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    return {"all_reduce": 2 * (n - 1) / n,
            "all_gather": (n - 1) / n,
            "reduce_scatter": (n - 1) / n,
            "all_to_all": (n - 1) / n,
            "ppermute": 1.0}[op]


def bench_collective(mesh, axis: str, op: str, nbytes: int,
                     reps: int = 10, dtype=jnp.float32) -> CollectiveResult:
    n = mesh.shape[axis]
    elems = max(128, nbytes // jnp.dtype(dtype).itemsize)
    elems = (elems // (128 * n)) * 128 * n or 128 * n
    x = buffers.init_pattern(elems, dtype=dtype).reshape(n, -1)

    if op == "all_reduce":
        body = lambda v: jax.lax.psum(v, axis)
        in_spec, out_spec = P(axis), P(axis)
    elif op == "all_gather":
        body = lambda v: jax.lax.all_gather(v, axis, tiled=True)
        in_spec, out_spec = P(axis), P()
    elif op == "reduce_scatter":
        # replicated input (n, m); each device ends with its (n/size, m) slice
        body = lambda v: jax.lax.psum_scatter(v, axis, tiled=True)
        in_spec, out_spec = P(), P(axis)
    elif op == "all_to_all":
        def body(v):  # local (1, m) -> (n, m/n) lanes -> a2a -> back to (1, m)
            w = jax.lax.all_to_all(v.reshape(n, -1), axis, 0, 0, tiled=False)
            return w.reshape(v.shape)
        in_spec, out_spec = P(axis), P(axis)
    elif op == "ppermute":
        perm = [(i, (i + 1) % n) for i in range(n)]
        body = lambda v: jax.lax.ppermute(v, axis, perm)
        in_spec, out_spec = P(axis), P(axis)
    else:
        raise KeyError(op)

    def fn(x):
        out = jax.shard_map(body, mesh=mesh, in_specs=in_spec,
                            out_specs=out_spec, check_vma=False)(x)
        return jax.tree.leaves(out)[0]

    fjit = jax.jit(fn)
    payload = x.size * x.dtype.itemsize // n      # per-device payload
    t = timing.time_fn(fjit, x, reps=reps, warmup=2, bytes_per_call=payload)
    link = payload * _ring_factor(op, n) / t.mean_s / 1e9
    return CollectiveResult(op=op, axis=axis, group_size=n,
                            nbytes=payload, mean_s=t.mean_s, std_s=t.std_s,
                            algo_gbps=payload / t.mean_s / 1e9, link_gbps=link)


def bench_all(mesh, nbytes: int = 4 * 2**20, ops=None, reps: int = 10):
    ops = ops or ["all_reduce", "all_gather", "reduce_scatter", "all_to_all",
                  "ppermute"]
    out = []
    for axis in mesh.axis_names:
        if mesh.shape[axis] < 2:
            continue
        for op in ops:
            out.append(bench_collective(mesh, axis, op, nbytes, reps=reps))
    return out
