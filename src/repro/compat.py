"""Forward-compatibility layer: run code written for jax >= 0.6 on jax 0.4.x.

The repo targets the modern sharding API (``jax.set_mesh``, ``jax.shard_map``
with ``check_vma``, ``jax.sharding.AxisType``); the pinned container toolchain
ships jax 0.4.37, which predates all three.  ``install()`` adds the missing
names to the ``jax`` module, delegating to their 0.4.x equivalents:

    jax.set_mesh(mesh)    -> ``with mesh:`` (Mesh has been a context manager
                             since the pjit era; entering it is the 0.4.x way
                             of establishing the ambient mesh)
    jax.shard_map(...)    -> jax.experimental.shard_map.shard_map, with
                             ``check_vma`` translated to ``check_rep``
    jax.sharding.AxisType -> a stub enum (0.4.x meshes have no axis types;
                             every axis behaves as Auto)
    jax.make_mesh(...)    -> the 0.4.x factory with an ``axis_types`` kwarg
                             accepted and dropped

Only ever *adds* attributes — on a modern jax this module is a no-op, so the
same source runs unchanged on both sides of the API break.
"""
from __future__ import annotations

import contextlib
import enum

import jax


class _AxisTypeStub(enum.Enum):
    """Placeholder for jax.sharding.AxisType on 0.4.x (everything is Auto)."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    from jax.experimental.shard_map import shard_map
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


@contextlib.contextmanager
def _set_mesh_compat(mesh):
    with mesh:
        yield mesh


def _wrap_make_mesh() -> None:
    import inspect
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    orig = jax.make_mesh

    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        return orig(axis_shapes, axis_names, **kw)

    make_mesh._compat_orig = orig
    jax.make_mesh = make_mesh


def install() -> None:
    """Idempotently add missing jax >= 0.6 names to the jax module."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh_compat
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypeStub
    _wrap_make_mesh()


install()
