"""Sharded checkpointing: per-leaf npz shards + JSON manifest, atomic publish,
async save, and **elastic restore** (a checkpoint written on mesh A restores
onto mesh B with different axis sizes — the resharding happens at load).

No orbax/tensorstore in this environment; the layout is deliberately simple:

    step_000100/
      manifest.json        {step, config_hash, mesh, tree structure, dtypes}
      <leaf-path>.npy      full logical array per leaf (gathered on save)
    LATEST                 -> step_000100   (atomic rename publish)

Saving gathers each leaf to host (addressable shards assembled); restoring
``device_put``s with the *target* mesh's NamedSharding — that is the elastic
path: nothing in the file format knows the mesh.  For multi-host production the
same layout shards per-host files by process index; this container is
single-process, so the gather is exact.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def config_hash(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def save(ckpt_dir: str | Path, step: int, tree, extra: Optional[dict] = None,
         blocking: bool = True) -> Path:
    """Write a checkpoint; returns the step directory.  With blocking=False the
    file writes happen on a background thread (the arrays are first fetched to
    host synchronously — cheap relative to the step — so training proceeds
    while the disk I/O runs: 1-step-decoupled async checkpointing)."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:08d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    leaves = _leaf_paths(tree)
    host_arrays = [(name, np.asarray(jax.device_get(leaf)))
                   for name, leaf in leaves]
    treedef = jax.tree.structure(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "treedef": str(treedef),
        "leaves": [{"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                   for n, a in host_arrays],
        "extra": extra or {},
    }

    def _write():
        for name, arr in host_arrays:
            p = tmp_dir / (name.replace("/", "__") + ".npy")
            np.save(p, arr)
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.rename(step_dir)                       # atomic publish
        latest = ckpt_dir / "LATEST"
        tmp_latest = ckpt_dir / ".LATEST.tmp"
        tmp_latest.write_text(step_dir.name)
        tmp_latest.rename(latest)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _ASYNC_THREADS.append(t)
    return step_dir


_ASYNC_THREADS: list[threading.Thread] = []


def wait_async():
    for t in _ASYNC_THREADS:
        t.join()
    _ASYNC_THREADS.clear()


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    latest = Path(ckpt_dir) / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    d = Path(ckpt_dir) / name
    if not (d / "manifest.json").exists():
        # torn write: fall back to newest complete step dir
        steps = sorted(Path(ckpt_dir).glob("step_*/manifest.json"))
        if not steps:
            return None
        d = steps[-1].parent
    return int(d.name.split("_")[1])


def restore(ckpt_dir: str | Path, tree_like, shardings=None,
            step: Optional[int] = None):
    """Restore into the structure of ``tree_like``.  ``shardings`` (matching
    pytree of NamedSharding) targets the *current* mesh — elastic by design."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())

    names = [e["name"] for e in manifest["leaves"]]
    leaves = _leaf_paths(tree_like)
    assert [n for n, _ in leaves] == names, "checkpoint/tree structure mismatch"

    if shardings is not None:
        # None entries mean "no target sharding" — count them as leaves so the
        # structure stays aligned with tree_like
        shard_leaves = jax.tree.flatten(
            shardings, is_leaf=lambda x: x is None)[0]
    else:
        shard_leaves = [None] * len(names)
    out = []
    for (name, like), sh in zip(leaves, shard_leaves):
        arr = np.load(step_dir / (name.replace("/", "__") + ".npy"))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(jax.tree.structure(tree_like), out), manifest
