"""Straggler detection — the paper's σ-reporting discipline, weaponized.

Arm-membench reports the standard deviation of every measurement series; a slow
HBM stack / downclocked chip shows up as a per-device throughput outlier long
before it shows up as a failed step.  ``probe_devices`` runs the membench
load_sum kernel *per device* and flags outliers; at scale the same probe runs
per host in the launcher's preflight, and ``StepTimer`` watches live step times
for drift (mid-run stragglers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core import buffers
from repro.core.instruction_mix import run_mix


@dataclass
class DeviceProbe:
    device: str
    gbps: float
    z_score: float
    is_straggler: bool


def probe_devices(nbytes: int = 4 * 2**20, passes: int = 4, reps: int = 5,
                  z_threshold: float = -3.0) -> list[DeviceProbe]:
    """Per-device load throughput; z < -3 (slower than fleet) flags straggler."""
    x_host = np.asarray(buffers.working_set(nbytes))
    results = []
    for dev in jax.devices():
        x = jax.device_put(x_host, dev)
        run_mix("load_sum", x, passes).block_until_ready()  # warm
        times = []
        for _ in range(reps):
            t0 = time.perf_counter_ns()
            run_mix("load_sum", x, passes).block_until_ready()
            times.append((time.perf_counter_ns() - t0) / 1e9)
        gbps = nbytes * passes / np.mean(times) / 1e9
        results.append([str(dev), gbps])
    vals = np.array([r[1] for r in results])
    mu, sd = vals.mean(), vals.std() + 1e-12
    return [DeviceProbe(device=r[0], gbps=r[1], z_score=(r[1] - mu) / sd,
                        is_straggler=(r[1] - mu) / sd < z_threshold)
            for r in results]


@dataclass
class StepTimer:
    """Online step-time monitor: EWMA + σ band; flags drift mid-run."""
    alpha: float = 0.05
    z_threshold: float = 4.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    slow_steps: list = field(default_factory=list)

    def update(self, step: int, dt: float) -> bool:
        if self.n < 5:  # burn-in
            self.mean = (self.mean * self.n + dt) / (self.n + 1)
            self.var = self.var * 0.5 + (dt - self.mean) ** 2 * 0.5
            self.n += 1
            return False
        sd = max(self.var ** 0.5, 1e-9)
        is_slow = (dt - self.mean) / sd > self.z_threshold
        if is_slow:
            self.slow_steps.append((step, dt))
        self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        self.var = (1 - self.alpha) * self.var + self.alpha * (dt - self.mean) ** 2
        self.n += 1
        return is_slow
