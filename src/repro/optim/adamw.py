"""AdamW with decoupled weight decay (optax is not available in this env).

Moments are f32 and share the parameter sharding (ZeRO: params are already
fully sharded over fsdp x model, so optimizer state is too — no extra wiring).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, state, grads):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype  # moments may be stored bf16 ("fit_single_pod" variant)
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
