"""Int8 error-feedback gradient compression for the DP reduction.

Grads are quantized to int8 with a per-tensor scale before the data-parallel
all-reduce (8x wire-byte reduction on the gradient traffic); the quantization
error is carried forward and added to the next step's gradient (error
feedback, Seide et al. / Karimireddy et al.) so the scheme stays convergent.
Unit-tested on a quadratic in tests/test_ft.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g):
    """g -> (int8 q, f32 scale); symmetric per-tensor."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error):
    """Returns (compressed-and-restored grads, new error).  The all-reduce in
    the surrounding pjit operates on the int8 payload; here we model the
    quantize -> (wire) -> dequantize round trip + error feedback."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32)
        deq = dequantize(q, scale)
        return deq, g32 - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
