"""Sequence-sharded decode attention (flash-decode with log-sum-exp combine).

For ``long_500k`` (batch 1, 524k-token KV cache) the batch axis cannot shard, so
the KV cache shards over the ``data`` axis on its *sequence* dim.  Each shard
computes partial attention over its local KV chunk plus a local log-sum-exp; the
numerically-stable combine is a psum of (exp-rescaled numerator, denominator)
pairs — the standard flash-decode reduction, expressed with shard_map + psum.

The single new (k, v) entry is written only by the shard that owns position
``pos`` (masked dynamic-update-slice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import gqa_project_qkv, rope_freqs
from repro.models.common import cast_compute


def seq_sharded_gqa_decode(ctx, cfg, p, x, cache_k, cache_v, pos):
    """x: (B,1,D); cache_(k|v): (B,S,KV,hd) sharded P(batch?, 'data', kv_heads?, None).

    Returns (out (B,1,D), new_k, new_v).
    """
    mesh = ctx.mesh
    seq_axis = "data"
    tp = ctx.tp_axis
    B, S, KV, hd = cache_k.shape
    H = cfg.n_heads
    G = H // KV
    n_shards = mesh.shape[seq_axis] if seq_axis in mesh.axis_names else 1
    S_local = S // n_shards

    inv_freq = rope_freqs(hd, cfg.rope_pct, cfg.rope_theta)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = gqa_project_qkv(cfg, p, x, positions, inv_freq)

    tp_ok = bool(tp) and tp in mesh.axis_names and KV % mesh.shape[tp] == 0 \
        and H % mesh.shape[tp] == 0
    tp_ax = tp if tp_ok else None
    kv_spec = P(None, seq_axis if n_shards > 1 else None, tp_ax, None)
    h_spec = P(None, None, tp_ax, None)      # (B, 1, heads, hd)
    o_spec = P(None, tp_ax, None, None)      # (B, KV, G, hd)

    def body(q, k_new, v_new, ck, cv):
        # shard-local coordinates
        sid = jax.lax.axis_index(seq_axis) if n_shards > 1 else 0
        start = sid * S_local
        rel = pos - start
        owns = (rel >= 0) & (rel < S_local)
        rel_c = jnp.clip(rel, 0, S_local - 1)
        k_upd = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype),
                                             (0, rel_c, 0, 0))
        ck = jnp.where(owns, k_upd, ck)
        v_upd = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype),
                                             (0, rel_c, 0, 0))
        cv = jnp.where(owns, v_upd, cv)

        qh = cast_compute(q).reshape(B, -1, G, hd)   # (B, KV_local, G, hd)
        s = jnp.einsum("bkgd,bjkd->bkgj", qh, cast_compute(ck),
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(jnp.float32(hd))
        valid = (jnp.arange(S_local)[None, None, None, :] + start) <= pos
        s = jnp.where(valid, s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)                    # local max
        e = jnp.exp(s - m)
        num = jnp.einsum("bkgj,bjkd->bkgd", e.astype(jnp.bfloat16),
                         cast_compute(cv), preferred_element_type=jnp.float32)
        den = jnp.sum(e, axis=-1)                                 # (B,KV,G)
        if n_shards > 1:
            gmax = jax.lax.pmax(m[..., 0], seq_axis)              # (B,KV,G)
            scale = jnp.exp(m[..., 0] - gmax)
            num = jax.lax.psum(num * scale[..., None], seq_axis)
            den = jax.lax.psum(den * scale, seq_axis)
        out = num / jnp.maximum(den, 1e-30)[..., None]            # (B,KV,G,hd)
        return out.astype(q.dtype), ck, cv

    if n_shards > 1 or tp_ok:
        shard_fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec),
            out_specs=(o_spec, kv_spec, kv_spec), check_vma=False)
        o, new_k, new_v = shard_fn(q, k_new, v_new, cache_k, cache_v)
    else:
        o, new_k, new_v = body(q, k_new, v_new, cache_k, cache_v)

    o = o.reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", cast_compute(o), cast_compute(p["wo"]))
    return out.astype(x.dtype), new_k, new_v
