"""repro — Arm-membench throughput benchmark, reproduced on the JAX/TPU stack.

Importing any ``repro`` subpackage installs the jax forward-compat layer
(see repro.compat): the codebase is written against the modern sharding API
and runs unchanged on the pinned jax 0.4.x toolchain.
"""
from repro import compat as _compat  # noqa: F401  (side effect: installs shims)
