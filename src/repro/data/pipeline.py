"""Deterministic synthetic token pipeline, sharded and resumable.

Each batch is generated from (seed, step) — restart at step k reproduces the
exact stream (checkpoint stores only the step counter).  Tokens follow a
Zipfian unigram draw with a short Markov mixing term so the loss curve has
learnable structure (pure uniform tokens give a flat ln V loss).  Batches are
``device_put`` against the batch sharding so each host only materializes its
addressable shard at scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    def __init__(self, cfg: DataConfig, sharding=None, frames_dim: int = 0,
                 n_audio_ctx: int = 0):
        self.cfg = cfg
        self.sharding = sharding
        self.frames_dim = frames_dim
        self.n_audio_ctx = n_audio_ctx
        # fixed Zipf unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(p / p.sum(), dtype=jnp.float32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S = cfg.global_batch, cfg.seq_len
        base = jax.random.choice(k1, cfg.vocab_size, (B, S + 1), p=self._probs)
        # Markov mixing: with prob 0.25 repeat the previous token (+1 mod V) —
        # gives the model a learnable bigram structure
        rep = jax.random.uniform(k2, (B, S + 1)) < 0.25
        shifted = jnp.roll(base, 1, axis=1)
        tokens = jnp.where(rep, (shifted + 1) % cfg.vocab_size, base)
        out = {"tokens": tokens[:, :S].astype(jnp.int32),
               "labels": tokens[:, 1:].astype(jnp.int32)}
        if self.frames_dim:
            out["frames"] = (jax.random.normal(
                k3, (B, self.n_audio_ctx, self.frames_dim), jnp.bfloat16) * 0.02)
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding[k]) for k, v in out.items()}
        return out


def make_pipeline(cfg_arch, shape, ctx=None, seed: int = 0):
    dcfg = DataConfig(vocab_size=cfg_arch.vocab_size, seq_len=shape[1]
                      if isinstance(shape, tuple) else shape.seq_len,
                      global_batch=shape[0] if isinstance(shape, tuple)
                      else shape.global_batch, seed=seed)
    sharding = None
    if ctx is not None:
        bs = ctx.sharding((dcfg.global_batch, dcfg.seq_len), ("batch", "seq"))
        sharding = {"tokens": bs, "labels": bs}
        if cfg_arch.family == "encdec":
            sharding["frames"] = ctx.sharding(
                (dcfg.global_batch, cfg_arch.n_audio_ctx, cfg_arch.d_model),
                ("batch", None, None))
    frames_dim = cfg_arch.d_model if cfg_arch.family == "encdec" else 0
    return SyntheticTokens(dcfg, sharding, frames_dim, cfg_arch.n_audio_ctx)
