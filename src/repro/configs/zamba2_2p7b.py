"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block every 6 layers.

54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64 vocab=32000.
[arXiv:2411.15242; hf]  Zamba2's parameter-shared transformer block is modeled as a
single shared (attn + FFN) block applied at every 6th layer with per-site input
norms (LoRA per-site deltas omitted — DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

ZAMBA2_2P7B = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=256),
    sub_quadratic=True,       # SSM backbone; shared-attn KV shards over seq for 500k
    source="[arXiv:2411.15242; hf]",
))
