from repro.configs.base import (
    SHAPES,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_arch,
    list_archs,
    param_count,
    reduced,
    register,
)

__all__ = [
    "SHAPES", "ArchConfig", "MLAConfig", "MoEConfig", "ShapeConfig", "SSMConfig",
    "get_arch", "list_archs", "param_count", "reduced", "register",
]
