"""deepseek-v2-236b [moe]: MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400. [arXiv:2405.04434; hf]
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

DEEPSEEK_V2_236B = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,           # MLA: kv heads == q heads after up-projection
    d_ff=1536,                # per-expert FFN width (assignment)
    vocab_size=102400,
    head_dim=192,             # nope 128 + rope 64
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    sub_quadratic=False,
    source="[arXiv:2405.04434; hf]",
))
