"""chameleon-34b [vlm]: early-fusion over VQ image tokens; qk-norm stability fix.

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536. [arXiv:2405.09818; unverified]
The VQ image tokenizer is the modality frontend stub: inputs are token ids drawn
from the unified 65536 vocab (text + image codes).
"""
from repro.configs.base import ArchConfig, register

CHAMELEON_34B = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    sub_quadratic=False,
    source="[arXiv:2405.09818; unverified]",
))
