"""mamba2-2.7b [ssm]: attention-free, SSD (state-space duality).

64L d_model=2560 ssm_state=128 vocab=50280. [arXiv:2405.21060; unverified]
d_inner = 2*d_model = 5120, head_dim 64 => 80 SSD heads.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_2P7B = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,               # SSD heads (d_inner / head_dim)
    n_kv_heads=0,             # attention-free
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    sub_quadratic=True,
    source="[arXiv:2405.21060; unverified]",
))
