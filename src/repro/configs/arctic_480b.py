"""arctic-480b [moe]: 128 experts top-2 + dense residual branch, GQA kv=8.

35L d_model=7168 56H d_ff=4864 vocab=32000. [hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                # dense-residual branch width
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  n_shared_experts=0, dense_residual=True),
    sub_quadratic=False,
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
))
