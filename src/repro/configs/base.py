"""Architecture + shape configuration system.

Every assigned architecture is expressed as an ``ArchConfig`` (one file per arch in
this package).  Shapes (the assigned input-shape set) are global and shared by all
LM-family archs.  ``REDUCED`` variants are derived mechanically for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch, and which step they lower.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    dense_residual: bool = False       # arctic: parallel dense FFN branch
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 => no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 => d_model // n_heads
    # variants / options
    norm: str = "rms"                  # rms | layer
    mlp: str = "swiglu"                # swiglu | gelu
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0              # stablelm: partial rotary
    qk_norm: bool = False              # chameleon
    tied_embeddings: bool = False      # granite
    logit_scale: float = 1.0           # granite (1/scale on logits)
    norm_eps: float = 1e-5
    # family payloads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0                # zamba2: shared attn block period
    n_encoder_layers: int = 0          # whisper
    n_audio_ctx: int = 1500            # whisper frontend-stub context
    # behaviour
    sub_quadratic: bool = False        # may run long_500k
    has_decode: bool = True            # encoder-only archs would set False
    dtype: str = "bfloat16"
    source: str = ""                   # provenance [source; tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def supports_shape(self, shape: ShapeConfig) -> tuple[bool, str]:
        """Whether this (arch x shape) cell is runnable, else the documented skip."""
        if shape.kind == "decode" and not self.has_decode:
            return False, "encoder-only arch has no decode step"
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, ("pure full-attention arch: 524288-token KV at batch 1 is "
                           "the quadratic case excluded by the brief (DESIGN.md §4)")
        return True, ""


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ArchConfig) -> ArchConfig:
    """Mechanically shrink a config to CPU-smoke scale, same family/topology."""
    updates: dict = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_audio_ctx=16,
    )
    if cfg.moe is not None:
        updates["moe"] = replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                   rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
    if cfg.ssm is not None:
        updates["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    if cfg.attn_every:
        updates["attn_every"] = 2
        updates["d_ff"] = 256
    if cfg.n_encoder_layers:
        updates["n_encoder_layers"] = 2
    return replace(cfg, **updates)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "whisper_medium", "deepseek_v2_236b", "arctic_480b", "chameleon_34b",
    "mamba2_2p7b", "internlm2_20b", "phi3_medium_14b", "stablelm_3b",
    "granite_3_2b", "zamba2_2p7b",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True


def param_count(cfg: ArchConfig) -> tuple[int, int]:
    """(total_params, active_params) analytic estimate — used for MODEL_FLOPS=6ND."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tied_embeddings else 2)

    def attn_params() -> int:
        if cfg.mla is not None:
            m = cfg.mla
            qdim = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
            q = d * qdim if not m.q_lora_rank else d * m.q_lora_rank + m.q_lora_rank * qdim
            kv_a = d * (m.kv_lora_rank + m.rope_head_dim)
            kv_b = m.kv_lora_rank * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
            o = cfg.n_heads * m.v_head_dim * d
            return q + kv_a + kv_b + o
        q = d * cfg.n_heads * hd
        kv = 2 * d * cfg.n_kv_heads * hd
        o = cfg.n_heads * hd * d
        return q + kv + o

    def dense_ffn(dff: int) -> int:
        return (3 if cfg.mlp == "swiglu" else 2) * d * dff

    def ssm_params(s: SSMConfig) -> int:
        d_in = s.expand * d
        nh = d_in // s.head_dim
        zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        return zxbcdt + d_in * d + nh * 2  # in-proj + out-proj + A_log/D

    per_layer: float
    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + dense_ffn(cfg.d_ff)
        active = per_layer
    elif cfg.family == "moe":
        m = cfg.moe
        moe_p = m.n_experts * 3 * d * m.d_ff_expert
        shared_p = m.n_shared_experts * 3 * d * m.d_ff_expert
        router = d * m.n_experts
        dense_res = dense_ffn(cfg.d_ff) if m.dense_residual else 0
        per_layer = attn_params() + moe_p + shared_p + router + dense_res
        active = (attn_params() + m.top_k * 3 * d * m.d_ff_expert + shared_p
                  + router + dense_res)
    elif cfg.family == "ssm":
        per_layer = ssm_params(cfg.ssm)
        active = per_layer
    elif cfg.family == "hybrid":
        per_layer = ssm_params(cfg.ssm)
        shared_attn = attn_params() + dense_ffn(cfg.d_ff)  # counted once
        total = L * per_layer + shared_attn + emb
        n_sites = L // cfg.attn_every if cfg.attn_every else 0
        act = L * per_layer + n_sites * 0 + shared_attn + emb
        return int(total), int(act)
    elif cfg.family == "encdec":
        enc = cfg.n_encoder_layers * (attn_params() + dense_ffn(cfg.d_ff))
        dec = L * (2 * attn_params() + dense_ffn(cfg.d_ff))  # self + cross
        return int(enc + dec + emb), int(enc + dec + emb)
    else:
        raise ValueError(cfg.family)
    return int(L * per_layer + emb), int(L * active + emb)
