"""whisper-medium [audio]: enc-dec, conv frontend stubbed (frame embeddings).

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. [arXiv:2212.04356; unverified]
Whisper-medium has 24 encoder + 24 decoder layers; ``n_layers`` counts the decoder
stack per the assignment, encoder depth recorded separately.
"""
from repro.configs.base import ArchConfig, register

WHISPER_MEDIUM = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layer",
    mlp="gelu",
    rope_pct=0.0,            # whisper uses learned/sinusoidal positions, no RoPE
    n_audio_ctx=1500,
    sub_quadratic=False,
    source="[arXiv:2212.04356; unverified]",
))
