"""stablelm-3b [dense]: MHA (kv=32), LayerNorm, partial rotary (25%).

32L d_model=2560 32H d_ff=6912 vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ArchConfig, register

STABLELM_3B = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layer",
    rope_pct=0.25,
    sub_quadratic=False,
    source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
))
