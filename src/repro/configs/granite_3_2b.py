"""granite-3-2b [dense]: GQA kv=8, tied embeddings, logit scaling.

40L d_model=2048 32H d_ff=8192 vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.configs.base import ArchConfig, register

GRANITE_3_2B = register(ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    tied_embeddings=True,
    logit_scale=8.0,
    sub_quadratic=False,
    source="[hf:ibm-granite/granite-3.0-2b-base; hf]",
))
