"""Mixture-of-Experts layer: shard_map expert parallelism.

Design (DESIGN.md §5): experts shard over the ``model`` axis (EP); tokens shard
over the data axes.  Routing is computed redundantly on every EP peer (cheap:
T x D x E), each peer processes only its local experts under a fixed per-expert
capacity, and one psum over ``model`` combines routed output, shared-expert
output and (arctic) the dense-residual branch.  Expert weights are ZeRO-3
sharded over the data axes and all-gathered (in bf16) inside the body.

This avoids all-to-all dispatch in the baseline; an a2a variant is a recorded
hillclimb candidate (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec, cast_compute


def moe_specs(cfg) -> dict:
    m, d = cfg.moe, cfg.d_model
    E, F = m.n_experts, m.d_ff_expert
    out = {
        "router": ParamSpec((d, E), ("embed", None), "normal", 0.02),
        "w_gate": ParamSpec((E, d, F), ("experts", "embed", None)),
        "w_up": ParamSpec((E, d, F), ("experts", "embed", None)),
        "w_down": ParamSpec((E, F, d), ("experts", None, "embed")),
    }
    if m.n_shared_experts:
        Fs = F * m.n_shared_experts
        out["shared_gate"] = ParamSpec((d, Fs), ("embed", "ffn"))
        out["shared_up"] = ParamSpec((d, Fs), ("embed", "ffn"))
        out["shared_down"] = ParamSpec((Fs, d), ("ffn", "embed"))
    if m.dense_residual:
        out["res_gate"] = ParamSpec((d, cfg.d_ff), ("embed", "ffn"))
        out["res_up"] = ParamSpec((d, cfg.d_ff), ("embed", "ffn"))
        out["res_down"] = ParamSpec((cfg.d_ff, d), ("ffn", "embed"))
    return out


def _ffn_partial(x, wg, wu, wd):
    """SwiGLU on a weight shard; output is a partial sum (psum later)."""
    g = x @ wg
    u = x @ wu
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    return h @ wd


def moe_layer(ctx, cfg, p: dict, x, *, capacity_factor=None,
              psum_dtype: str = "float32"):
    """x: (B, S, D) sharded P(dp, None, None).  Returns (y, aux_loss)."""
    m = cfg.moe
    mesh = ctx.mesh
    tp = ctx.tp_axis or "model"
    dp = ctx.dp_axes
    fsdp = ctx.fsdp_axes
    ep_size = mesh.shape[tp] if tp in mesh.axis_names else 1
    E, K, F, D = m.n_experts, m.top_k, m.d_ff_expert, cfg.d_model
    assert E % ep_size == 0, (E, ep_size)
    E_local = E // ep_size

    B, S, _ = x.shape
    dp_size = ctx.axis_size(*dp) if dp else 1
    assert B % dp_size == 0, (B, dp_size)
    T = (B // dp_size) * S
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(1, math.ceil(T * K / E * cf))

    fsdp_tuple = tuple(fsdp)
    gather_ok = bool(fsdp_tuple) and ctx.axis_size(*fsdp_tuple) > 1

    def body(xb, router_w, wg, wu, wd, *rest):
        rest = list(rest)
        Bl, Sl, _ = xb.shape
        xf = cast_compute(xb.reshape(Bl * Sl, D))

        # ZeRO-3: gather expert shards over the data axes (bf16 to halve traffic)
        def gather(w, axis):
            wc = cast_compute(w)
            if gather_ok:
                wc = jax.lax.all_gather(wc, fsdp_tuple, axis=axis, tiled=True)
            return wc
        wg_f = gather(wg, 1)          # (E_local, D, F)   — D is the fsdp shard
        wu_f = gather(wu, 1)
        wd_f = gather(wd, 2)          # (E_local, F, D)   — D is the fsdp shard

        # --- routing (replicated across EP peers) ---
        logits = (xf @ cast_compute(router_w)).astype(jnp.float32)   # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, K)                          # (T, K)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

        # --- capacity dispatch to local experts ---
        # Slot bookkeeping runs on narrow (T*K, E_local) int tensors; the D-wide
        # gathers/scatters loop over the K choices so no (T*K, D) tensor is ever
        # materialized (K x token activation memory otherwise).
        e0 = jax.lax.axis_index(tp) * E_local if tp in mesh.axis_names else 0
        flat_e = topi.reshape(-1)                                     # (T*K,)
        le = flat_e - e0
        local = (le >= 0) & (le < E_local)
        onehot = (le[:, None] == jnp.arange(E_local)[None, :]) & local[:, None]
        pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) * onehot   # 1-based
        keep = onehot & (pos <= C)
        slot_mat = jnp.where(keep, le[:, None] * C + pos - 1, 0)
        kept = jnp.any(keep, axis=1)
        flat_slot = jnp.where(kept, jnp.sum(slot_mat, axis=1), E_local * C)
        slot_tk = flat_slot.reshape(T, K)
        kept_tk = kept.reshape(T, K)

        buf = jnp.zeros((E_local * C + 1, D), xf.dtype)
        for kk in range(K):   # K static scatters of (T, D) — no T*K blowup
            buf = buf.at[slot_tk[:, kk]].set(xf, mode="drop")
        xe = buf[:E_local * C].reshape(E_local, C, D)

        # --- expert FFN (batched over local experts) ---
        g = jnp.einsum("ecd,edf->ecf", xe, wg_f)
        u = jnp.einsum("ecd,edf->ecf", xe, wu_f)
        h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xe.dtype)
        ye = jnp.einsum("ecf,efd->ecd", h, wd_f).reshape(E_local * C, D)
        ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)

        # --- combine (K gathers of (T, D), f32 accumulation) ---
        out = jnp.zeros((T, D), jnp.float32)
        for kk in range(K):
            w_k = (topv[:, kk] * kept_tk[:, kk]).astype(jnp.float32)
            out = out + ye[slot_tk[:, kk]].astype(jnp.float32) * w_k[:, None]

        # --- shared experts / dense residual: TP partials on the ffn shard ---
        idx = 0
        if m.n_shared_experts:
            sg, su, sd = rest[idx], rest[idx + 1], rest[idx + 2]
            idx += 3
            out = out + _ffn_partial(xf, gather(sg, 0), gather(su, 0),
                                     gather(sd, 1)).astype(jnp.float32)
        if m.dense_residual:
            rg, ru, rd = rest[idx], rest[idx + 1], rest[idx + 2]
            idx += 3
            out = out + _ffn_partial(xf, gather(rg, 0), gather(ru, 0),
                                     gather(rd, 1)).astype(jnp.float32)

        if tp in mesh.axis_names and mesh.shape[tp] > 1:
            out = jax.lax.psum(out.astype(jnp.dtype(psum_dtype)), tp)

        # --- load-balance aux (Switch-style), averaged over the whole mesh ---
        frac = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=(0, 1)) * E
        pmean = jnp.mean(probs, axis=0)
        aux = jnp.sum(frac * pmean)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out.reshape(Bl, Sl, D).astype(xb.dtype), aux

    # ---- shard_map plumbing ----
    dp_spec = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)
    x_spec = P(dp_spec, None, None)
    fs = fsdp_tuple if len(fsdp_tuple) > 1 else (fsdp_tuple[0] if fsdp_tuple else None)
    tp_s = tp if tp in mesh.axis_names else None

    in_specs = [x_spec, P(None, None),
                P(tp_s, fs, None), P(tp_s, fs, None), P(tp_s, None, fs)]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    # shared/residual: (D, F) sharded (fsdp, model); (F, D) sharded (model, fsdp)
    for names in (("shared_gate", "shared_up", "shared_down"),
                  ("res_gate", "res_up", "res_down")):
        if names[0] in p:
            in_specs += [P(fs, tp_s), P(fs, tp_s), P(tp_s, fs)]
            args += [p[names[0]], p[names[1]], p[names[2]]]

    shard_fn = jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                             out_specs=(x_spec, P()), check_vma=False)
    return shard_fn(*args)
