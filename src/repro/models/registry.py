"""Model registry: one interface over all assigned families.

``build(cfg)`` returns a model object exposing:
    param_specs() -> ParamSpec pytree
    loss(params, batch, ctx, variant) -> (scalar, metrics)
    prefill(params, <tokens|batch>, ctx, variant) -> (logits, cache)
    decode_step(params, cache, tokens, pos, ctx, variant) -> (logits, cache)

This module adds the pieces shared by launch/tests: abstract input specs per
assigned shape, stacked cache specs with logical axes, and batch construction.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.ssm_lm import SSMLM
from repro.models.transformer import DecoderLM


def build(cfg: ArchConfig):
    if cfg.family in ("dense", "vlm", "moe"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return SSMLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins — no allocation; dry-run pattern)
# ---------------------------------------------------------------------------

def input_abstract(cfg: ArchConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """Returns (abstract batch dict, logical-axes dict) for the step function."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    ax = ("batch", "seq")
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        axes = {"tokens": ax, "labels": ax}
    elif shape.kind == "prefill":
        batch = {"tokens": tok}
        axes = {"tokens": ax}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        axes = {"tokens": ("batch", None)}
    if cfg.family == "encdec":
        frames = jax.ShapeDtypeStruct((B, cfg.n_audio_ctx, cfg.d_model),
                                      jnp.bfloat16)
        if shape.kind in ("train", "prefill"):
            batch["frames"] = frames
            axes["frames"] = ("batch", None, None)
    return batch, axes


def make_batch(cfg: ArchConfig, shape_or_bs, rng: jax.Array):
    """Concrete random batch (smoke tests / examples)."""
    if isinstance(shape_or_bs, tuple):
        B, S = shape_or_bs
    else:
        B, S = shape_or_bs.global_batch, shape_or_bs.seq_len
    r1, r2 = jax.random.split(rng)
    tokens = jax.random.randint(r1, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            r2, (B, cfg.n_audio_ctx, cfg.d_model), jnp.bfloat16) * 0.02
    return batch


# ---------------------------------------------------------------------------
# Cache specs (stacked over layers/sites) with logical axes
# ---------------------------------------------------------------------------

def cache_abstract(cfg: ArchConfig, batch: int, seq_len: int) -> tuple[dict, dict]:
    """(abstract cache pytree, logical-axes pytree), stacked per family."""
    model = build(cfg)
    shapes = model.cache_shapes(batch, seq_len)

    def entry(spec, lead):
        shp, axes, dtype = spec
        return (jax.ShapeDtypeStruct(lead + shp, dtype),
                (None,) * len(lead) + axes)

    if cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        group = cfg.attn_every
        abs_t: dict = {"ssm": {}}
        ax_t: dict = {"ssm": {}}
        for k, spec in shapes["ssm"].items():
            abs_t["ssm"][k], ax_t["ssm"][k] = entry(spec, (n_sites, group))
        for k in ("k", "v"):
            abs_t[k], ax_t[k] = entry(shapes[k], (n_sites,))
        return abs_t, ax_t

    lead = (cfg.n_layers,)
    abs_t, ax_t = {}, {}
    for k, spec in shapes.items():
        abs_t[k], ax_t[k] = entry(spec, lead)
    return abs_t, ax_t


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    """Concrete zero-filled cache (smoke tests / serving examples)."""
    abs_t, _ = cache_abstract(cfg, batch, seq_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abs_t)
