"""Decoder-only LM covering the dense, vlm (early-fusion) and moe families.

Layers are scan-stacked: block params carry a leading (L, ...) dim and the
forward pass is one ``lax.scan`` — HLO size is O(1) in depth, which keeps the
512-device dry-run compiles tractable and matches production practice (MaxText).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.common import (ParamSpec, apply_mlp, apply_norm,
                                 chunked_softmax_xent, embed_specs, embed_tokens,
                                 lm_logits, mlp_specs, norm_specs, stack_specs)
from repro.models.variant import BASELINE, Variant, remat_wrap


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.is_moe = cfg.moe is not None
        self.is_mla = cfg.mla is not None

    # -- parameters ----------------------------------------------------------
    def block_specs(self) -> dict:
        cfg = self.cfg
        block = {
            "ln1": norm_specs(cfg, cfg.d_model),
            "attn": (mla_mod.mla_specs(cfg) if self.is_mla
                     else attn.gqa_specs(cfg, cfg.d_model)),
            "ln2": norm_specs(cfg, cfg.d_model),
        }
        if self.is_moe:
            block["moe"] = moe_mod.moe_specs(cfg)
        else:
            block["mlp"] = mlp_specs(cfg, cfg.d_model, cfg.d_ff)
        return block

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": embed_specs(cfg),
            "blocks": stack_specs(self.block_specs(), cfg.n_layers),
            "ln_f": norm_specs(cfg, cfg.d_model),
        }

    # -- forward -------------------------------------------------------------
    def _block(self, p, x, ctx, variant: Variant, positions):
        cfg = self.cfg
        h = apply_norm(cfg, p["ln1"], x)
        if self.is_mla:
            a = mla_mod.mla_attention(cfg, p["attn"], h, positions=positions,
                                      kv_block=variant.kv_block,
                                      variant=variant.attn_variant, ctx=ctx,
                                      unroll=variant.unroll)
        else:
            a = attn.gqa_attention(cfg, p["attn"], h, causal=True,
                                   positions=positions,
                                   kv_block=variant.kv_block,
                                   variant=variant.attn_variant, ctx=ctx,
                                   unroll=variant.unroll)
        x = x + a
        h = apply_norm(cfg, p["ln2"], x)
        aux = jnp.zeros((), jnp.float32)
        if self.is_moe:
            y, aux = moe_mod.moe_layer(ctx, cfg, p["moe"], h,
                                       capacity_factor=variant.moe_capacity_factor,
                                       psum_dtype=variant.psum_dtype)
        else:
            y = apply_mlp(cfg, p["mlp"], h)
        return x + y, aux

    def hidden_states(self, params, tokens, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        x = ctx.constrain(x, "batch", "act_seq", None)
        positions = jnp.arange(S)

        def body(carry, layer_p):
            x, aux = carry
            x = ctx.constrain(x, "batch", "act_seq", None)
            y, a = self._block(layer_p, x, ctx, variant, positions)
            return (y, aux + a), None

        block_fn = remat_wrap(body, variant)
        (x, aux), _ = jax.lax.scan(block_fn,
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        x = apply_norm(cfg, params["ln_f"], x)
        return x, aux / cfg.n_layers

    def loss(self, params, batch, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        h, aux = self.hidden_states(params, batch["tokens"], ctx, variant)
        xent = chunked_softmax_xent(cfg, params["embed"], h, batch["labels"],
                                    chunk=variant.xent_chunk,
                                    unroll=variant.unroll)
        loss = xent
        if self.is_moe:
            loss = loss + cfg.moe.aux_loss_weight * aux
        return loss, {"xent": xent, "aux": aux}

    # -- serving ---------------------------------------------------------------
    def cache_shapes(self, batch: int, seq_len: int) -> dict:
        """Per-layer cache entry shapes/axes (stacked over layers by caller)."""
        cfg = self.cfg
        if self.is_mla:
            m = cfg.mla
            return {
                "c": ((batch, seq_len, m.kv_lora_rank),
                      ("batch", "kv_seq", None), jnp.bfloat16),
                "k_rope": ((batch, seq_len, m.rope_head_dim),
                           ("batch", "kv_seq", None), jnp.bfloat16),
            }
        hd = cfg.resolved_head_dim
        return {
            "k": ((batch, seq_len, cfg.n_kv_heads, hd),
                  ("batch", "kv_seq", "kv_heads", None), jnp.bfloat16),
            "v": ((batch, seq_len, cfg.n_kv_heads, hd),
                  ("batch", "kv_seq", "kv_heads", None), jnp.bfloat16),
        }

    def prefill(self, params, tokens, ctx, variant: Variant = BASELINE):
        """Full-sequence forward that also emits the per-layer cache.

        Returns (last-position logits (B, V), cache stacked (L, ...)).
        """
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        positions = jnp.arange(S)

        def body(carry, layer_p):
            x = carry
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, layer_p["ln1"], x)
            if self.is_mla:
                m = cfg.mla
                inv = attn.rope_freqs(m.rope_head_dim, 1.0, cfg.rope_theta)
                qn, qr, c, kr = mla_mod._project_latent(cfg, layer_p["attn"], h,
                                                        positions, inv)
                k_nope = jnp.einsum("bsr,rhk->bshk", c,
                                    layer_p["attn"]["w_uk"].astype(qn.dtype))
                v = jnp.einsum("bsr,rhk->bshk", c,
                               layer_p["attn"]["w_uv"].astype(qn.dtype))
                kr_h = jnp.broadcast_to(kr[:, :, None, :],
                                        (B, S, cfg.n_heads, m.rope_head_dim))
                q = jnp.concatenate([qn, qr], axis=-1)
                k = jnp.concatenate([k_nope, kr_h], axis=-1)
                o = attn.chunked_attention(q, k, v, causal=True,
                                           kv_block=min(variant.kv_block, S),
                                           ctx=ctx)
                a = jnp.einsum("bshk,hkd->bsd", o,
                               layer_p["attn"]["wo"].astype(o.dtype)).astype(x.dtype)
                entry = {"c": c.astype(jnp.bfloat16),
                         "k_rope": kr.astype(jnp.bfloat16)}
            else:
                inv = attn.rope_freqs(cfg.resolved_head_dim, cfg.rope_pct,
                                      cfg.rope_theta)
                q, k, v = attn.gqa_project_qkv(cfg, layer_p["attn"], h,
                                               positions, inv)
                o = attn.chunked_attention(q, k, v, causal=True,
                                           kv_block=min(variant.kv_block, S),
                                           ctx=ctx)
                a = jnp.einsum("bshk,hkd->bsd", o,
                               layer_p["attn"]["wo"].astype(o.dtype)).astype(x.dtype)
                entry = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
            x = x + a
            h = apply_norm(cfg, layer_p["ln2"], x)
            if self.is_moe:
                y, _ = moe_mod.moe_layer(ctx, cfg, layer_p["moe"], h,
                                         capacity_factor=variant.moe_capacity_factor,
                                         psum_dtype=variant.psum_dtype)
            else:
                y = apply_mlp(cfg, layer_p["mlp"], h)
            return x + y, entry

        block_fn = remat_wrap(body, variant)
        x, cache = jax.lax.scan(block_fn, x, params["blocks"])
        x = apply_norm(cfg, params["ln_f"], x[:, -1:, :])
        logits = lm_logits(cfg, params["embed"], x)[:, 0]
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, ctx,
                    variant: Variant = BASELINE):
        """tokens: (B, 1); cache: stacked (L, ...) pytree; pos: scalar int32."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens)

        def body(x, xs):
            layer_p, layer_cache = xs
            h = apply_norm(cfg, layer_p["ln1"], x)
            if self.is_mla:
                a, c, kr = mla_mod.mla_decode(cfg, layer_p["attn"], h,
                                              layer_cache["c"],
                                              layer_cache["k_rope"], pos)
                new_cache = {"c": c, "k_rope": kr}
            else:
                a, ck, cv = attn.gqa_decode(cfg, layer_p["attn"], h,
                                            layer_cache["k"], layer_cache["v"], pos)
                new_cache = {"k": ck, "v": cv}
            x = x + a
            h = apply_norm(cfg, layer_p["ln2"], x)
            if self.is_moe:
                y, _ = moe_mod.moe_layer(ctx, cfg, layer_p["moe"], h,
                                         capacity_factor=variant.moe_capacity_factor,
                                         psum_dtype=variant.psum_dtype)
            else:
                y = apply_mlp(cfg, layer_p["mlp"], h)
            return x + y, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = apply_norm(cfg, params["ln_f"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_cache
