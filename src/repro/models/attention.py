"""Attention: GQA with (partial) RoPE, chunked online-softmax, MLA, decode paths.

The XLA implementation (``chunked_attention``) is the default everywhere: it is the
pure-jnp oracle for the Pallas flash kernel and keeps peak memory O(S * block)
instead of O(S^2), which is what lets 32k-token prefill *fit* in the dry-run.
``implementation='pallas'`` switches the hot spot to kernels/flash_attention on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, cast_compute, rms_norm

# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    rot = int(head_dim * rope_pct) // 2 * 2
    if rot == 0:
        return None
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x, positions, inv_freq):
    """x: (B, S, H, Dh); positions: (B, S) or (S,). Rotates the first rot dims."""
    if inv_freq is None:
        return x
    rot = inv_freq.shape[0] * 2
    xf = x.astype(jnp.float32)
    x_rot, x_pass = xf[..., :rot], xf[..., rot:]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv_freq[None, None, :]  # (B,S,r/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    x_rot = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([x_rot, x_pass], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (flash-style, XLA path)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, kv_block: int = 1024,
                      q_block: int = 1024, q_positions=None, kv_positions=None,
                      ctx=None, unroll: bool = False):
    """q: (B, Sq, H, Dh); k/v: (B, Sk, KV, Dh|Dv).  GQA via head repetition at the
    einsum level (no materialized repeat).  Returns (B, Sq, H, Dv).

    Online softmax, blocked over BOTH query and KV: temporaries are
    O(q_block * kv_block) per head, never O(Sq * Sk).  ``ctx`` adds
    heads->model sharding constraints (Megatron-style TP attention).
    """
    if ctx is not None:
        q, k, v = _constrain_qkv(ctx, q, k, v)
    B, Sq, H, Dh = q.shape
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    # outer blocking over queries (scan) when the sequence is long
    if Sq > q_block and Sq % q_block == 0:
        nqb = Sq // q_block
        qs = q.reshape(B, nqb, q_block, H, Dh).swapaxes(0, 1)
        qps = q_positions.reshape(nqb, q_block)

        def q_body(_, blk):
            q_b, qp_b = blk
            o = _kv_scan_attention(q_b, k, v, causal=causal, kv_block=kv_block,
                                   q_positions=qp_b, kv_positions=kv_positions,
                                   unroll=unroll)
            return None, o

        _, outs = jax.lax.scan(q_body, None, (qs, qps), unroll=unroll)
        out = outs.swapaxes(0, 1).reshape(B, Sq, H, -1)
        if ctx is not None:
            out = _constrain_attn_out(ctx, out)
        return out
    out = _kv_scan_attention(q, k, v, causal=causal, kv_block=kv_block,
                             q_positions=q_positions, kv_positions=kv_positions,
                             unroll=unroll)
    if ctx is not None:
        out = _constrain_attn_out(ctx, out)
    return out


def _constrain_qkv(ctx, q, k, v):
    """Megatron TP: heads -> model.  When the head count doesn't divide the
    model axis (phi3: 40 heads / 16), fall back to sharding the *sequence* dim
    over model so attention temporaries never replicate."""
    H = q.shape[2]
    if ctx.resolve_dim("act_heads", H) is not None:
        q = ctx.constrain(q, "batch", None, "act_heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)
    else:
        q = ctx.constrain(q, "batch", "act_seq", None, None)
        k = ctx.constrain(k, "batch", "act_seq", None, None)
        v = ctx.constrain(v, "batch", "act_seq", None, None)
    return q, k, v


def _constrain_attn_out(ctx, out):
    if ctx.resolve_dim("act_heads", out.shape[2]) is not None:
        return ctx.constrain(out, "batch", None, "act_heads", None)
    return ctx.constrain(out, "batch", "act_seq", None, None)


def _kv_scan_attention(q, k, v, *, causal: bool, kv_block: int,
                       q_positions, kv_positions=None, unroll: bool = False):
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV  # query heads per kv head
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    kv_block = min(kv_block, Sk)

    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk)

    # pad KV to a block multiple; padded slots masked out via kv_valid
    pad = (-Sk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=0)
    kv_valid = jnp.arange(Sk + pad) < Sk
    Sk = Sk + pad
    n_blocks = Sk // kv_block

    qc = cast_compute(q).reshape(B, Sq, KV, G, Dh)

    # jax.checkpoint = the flash-attention backward: never save the (q, kb)
    # score/prob blocks — recompute them from the saved block inputs.  Without
    # this, scan's backward stacks every probability block (O(Sq*Sk) f32).
    @jax.checkpoint
    def body(carry, blk):
        m, l, acc = carry
        k_b, v_b, kpos_b, kval_b = blk  # (B,kb,KV,Dh), (B,kb,KV,Dv), (kb,), (kb,)
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qc, cast_compute(k_b),
                       preferred_element_type=jnp.float32) * scale  # (B,KV,G,Sq,kb)
        mask = kval_b[None, None, None, None, :]
        if causal:
            mask = mask & (q_positions[None, None, None, :, None]
                           >= kpos_b[None, None, None, None, :])
        # -1e30, not -inf: a fully-masked block would make m == -inf and
        # exp(-inf - -inf) == nan in the online-softmax update.
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(cast_compute(v_b).dtype),
                        cast_compute(v_b), preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, Dv), jnp.float32)

    ks = k.reshape(B, n_blocks, kv_block, KV, Dh).swapaxes(0, 1)
    vs = v.reshape(B, n_blocks, kv_block, KV, Dv).swapaxes(0, 1)
    kps = kv_positions.reshape(n_blocks, kv_block)
    kvs = kv_valid.reshape(n_blocks, kv_block)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps, kvs),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,Sq,Dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def folded_causal_attention(q, k, v, *, q_block: int = 1024, kv_block: int = 1024,
                            ctx=None, unroll: bool = False):
    """Causal attention that does ~half the block work of ``chunked_attention``.

    Scans query blocks; for query block i only KV blocks [0, i] are visited, by
    slicing a *static* prefix via masking-free pairing: query block i processes
    exactly (i+1) kv blocks through ``lax.fori``-style dynamic slice.  Work is
    sum_i (i+1) = N(N+1)/2 blocks vs N^2 for the masked full scan.
    """
    if ctx is not None:
        q, k, v = _constrain_qkv(ctx, q, k, v)
    B, S, H, Dh = q.shape
    assert S % q_block == 0 and S % kv_block == 0 and q_block == kv_block
    nq = S // q_block
    if nq <= 1:
        return chunked_attention(q, k, v, causal=True, kv_block=kv_block)

    # Each query block i only visits KV blocks [0, i]: total block-pairs
    # nq(nq+1)/2 vs nq^2 for the masked full scan.  The per-i KV prefix length is
    # static (trace-time python loop), so no masking waste and no dynamic shapes.
    qs = q.reshape(B, nq, q_block, H, Dh)
    outs = []
    for i in range(nq):
        kv_len = (i + 1) * kv_block
        k_i = jax.lax.slice_in_dim(k, 0, kv_len, axis=1)
        v_i = jax.lax.slice_in_dim(v, 0, kv_len, axis=1)
        q_pos = jnp.arange(q_block) + i * q_block
        outs.append(_kv_scan_attention(
            qs[:, i], k_i, v_i, causal=True, kv_block=kv_block,
            q_positions=q_pos, kv_positions=jnp.arange(kv_len), unroll=unroll))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# GQA attention layer (params + train/prefill/decode application)
# ---------------------------------------------------------------------------

def gqa_specs(cfg, d: int) -> dict:
    hd = cfg.resolved_head_dim
    out = {
        "wq": ParamSpec((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
        out["k_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
    return out


def gqa_project_qkv(cfg, p: dict, x, positions, inv_freq):
    xc = cast_compute(x)
    q = jnp.einsum("bsd,dhk->bshk", xc, cast_compute(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", xc, cast_compute(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", xc, cast_compute(p["wv"]))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    return q, k, v


def gqa_attention(cfg, p: dict, x, *, causal: bool = True, positions=None,
                  kv_block: int = 1024, variant: str = "masked", ctx=None,
                  unroll: bool = False):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    inv_freq = rope_freqs(cfg.resolved_head_dim, cfg.rope_pct, cfg.rope_theta)
    q, k, v = gqa_project_qkv(cfg, p, x, positions, inv_freq)
    if causal and variant == "folded" and S > kv_block and S % kv_block == 0:
        o = folded_causal_attention(q, k, v, q_block=kv_block, kv_block=kv_block,
                                    ctx=ctx, unroll=unroll)
    else:
        o = chunked_attention(q, k, v, causal=causal, kv_block=min(kv_block, S),
                              ctx=ctx, unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", o, cast_compute(p["wo"])).astype(x.dtype)


def gqa_decode(cfg, p: dict, x, cache_k, cache_v, pos):
    """x: (B, 1, D); cache_(k|v): (B, Smax, KV, Dh); pos: scalar int32.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B, _, D = x.shape
    hd = cfg.resolved_head_dim
    inv_freq = rope_freqs(hd, cfg.rope_pct, cfg.rope_theta)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = gqa_project_qkv(cfg, p, x, positions, inv_freq)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    Smax = cache_k.shape[1]
    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    s = jnp.einsum("bkgd,bjkd->bkgj", cast_compute(q).reshape(B, KV, G, hd),
                   cast_compute(cache_k), preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    mask = jnp.arange(Smax)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", w.astype(jnp.bfloat16), cast_compute(cache_v),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, cfg.n_heads, -1).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", cast_compute(o), cast_compute(p["wo"]))
    return out.astype(x.dtype), cache_k, cache_v
