"""Execution variants — the knobs the §Perf hillclimb turns."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Variant:
    name: str = "baseline"
    attn_variant: str = "masked"     # masked | folded (causal block skipping)
    kv_block: int = 1024             # online-softmax KV block
    remat: str = "full"              # full | dots | none
    xent_chunk: int = 512            # chunked cross-entropy sequence block
    moe_capacity_factor: float | None = None
    psum_dtype: str = "float32"      # MoE combine psum precision (bf16 = beyond-paper)
    use_pallas: bool = False         # TPU-only: flash-attention / SSD kernels
    accum_steps: int = 1             # gradient-accumulation microbatches
    adam_dtype: str = "float32"      # Adam moment storage (bf16 halves opt state)
    unroll: bool = False             # unroll attention/xent scans (cost probes:
                                     # XLA-CPU cost analysis counts loop bodies
                                     # once — verified in EXPERIMENTS.md)
    cast_params: bool = False        # cast f32 params->bf16 at step entry so
                                     # FSDP all-gathers carry half the bytes
    kv_cache_dtype: str = "bfloat16" # fp8 cache halves decode HBM traffic
    seq_parallel: bool = True        # shard residual seq dim over model (SP);
                                     # False = pure-TP (fewer reshard hops,
                                     # larger saved activations)
    cache_layout: str = "seq"        # decode KV cache: shard "seq" or "heads"
                                     # over the model axis


BASELINE = Variant()

# Named variants — the §Perf hillclimb moves through these.
VARIANTS: dict[str, Variant] = {
    "baseline": BASELINE,
    # beyond-paper candidates (see EXPERIMENTS.md §Perf for the iteration log)
    "folded_attn": replace(BASELINE, name="folded_attn", attn_variant="folded"),
    "remat_dots": replace(BASELINE, name="remat_dots", remat="dots"),
    "kvblock_2048": replace(BASELINE, name="kvblock_2048", kv_block=2048),
    "kvblock_4096": replace(BASELINE, name="kvblock_4096", kv_block=4096),
    "xent_2048": replace(BASELINE, name="xent_2048", xent_chunk=2048),
    "cap_1.0": replace(BASELINE, name="cap_1.0", moe_capacity_factor=1.0),
    "folded_remat_dots": replace(BASELINE, name="folded_remat_dots",
                                 attn_variant="folded", remat="dots"),
    # single-pod fit for the 200B+ archs: f32 p+m+v alone is 11.4 GiB/device at
    # 256-way sharding; bf16 moments + microbatching is the standard remedy.
    "fit_single_pod": replace(BASELINE, name="fit_single_pod",
                              adam_dtype="bfloat16", accum_steps=4),
    "accum4": replace(BASELINE, name="accum4", accum_steps=4),
    # --- §Perf hillclimb ladder (beyond-paper optimizations) ---
    "cast_bf16": replace(BASELINE, name="cast_bf16", cast_params=True),
    "cast_folded": replace(BASELINE, name="cast_folded", cast_params=True,
                           attn_variant="folded"),
    "cast_dots": replace(BASELINE, name="cast_dots", cast_params=True,
                         remat="dots"),
    "cast_folded_dots": replace(BASELINE, name="cast_folded_dots",
                                cast_params=True, attn_variant="folded",
                                remat="dots"),
    "fp8_cache": replace(BASELINE, name="fp8_cache",
                         kv_cache_dtype="float8_e4m3fn"),
    "fp8_heads": replace(BASELINE, name="fp8_heads",
                         kv_cache_dtype="float8_e4m3fn",
                         cache_layout="heads"),
    "moe_opt": replace(BASELINE, name="moe_opt", cast_params=True,
                       psum_dtype="bfloat16", moe_capacity_factor=1.0),
    "moe_opt_accum": replace(BASELINE, name="moe_opt_accum", cast_params=True,
                             psum_dtype="bfloat16", moe_capacity_factor=1.0,
                             accum_steps=4, adam_dtype="bfloat16"),
    "nosp": replace(BASELINE, name="nosp", seq_parallel=False),
    "cast_dots_nosp": replace(BASELINE, name="cast_dots_nosp",
                              cast_params=True, remat="dots",
                              seq_parallel=False),
    "dots_nosp_accum": replace(BASELINE, name="dots_nosp_accum",
                               cast_params=True, remat="dots",
                               seq_parallel=False, accum_steps=4),
    "best_a": replace(BASELINE, name="best_a", cast_params=True, remat="dots",
                      seq_parallel=False, attn_variant="folded"),
    "nosp_accum4": replace(BASELINE, name="nosp_accum4", cast_params=True,
                           seq_parallel=False, accum_steps=4),
    "accum2_folded": replace(BASELINE, name="accum2_folded", cast_params=True,
                             attn_variant="folded", accum_steps=2),
    "moe_best": replace(BASELINE, name="moe_best", cast_params=True,
                        psum_dtype="bfloat16", moe_capacity_factor=1.0,
                        remat="dots", seq_parallel=False),
    "moe_dots_sp": replace(BASELINE, name="moe_dots_sp", cast_params=True,
                           psum_dtype="bfloat16", moe_capacity_factor=1.0,
                           remat="dots", accum_steps=2),
    "moe_dots_accum4": replace(BASELINE, name="moe_dots_accum4",
                               cast_params=True, psum_dtype="bfloat16",
                               moe_capacity_factor=1.0, remat="dots",
                               accum_steps=4, adam_dtype="bfloat16"),
}


def apply_rules(ctx, variant: Variant):
    """Adjust a ShardCtx's logical rules for variant-level sharding choices."""
    if not variant.seq_parallel:
        ctx.rules["act_seq"] = [None]
    if variant.cache_layout == "heads":
        # KV heads take the model axis; cache seq stays local per shard =>
        # no cross-shard softmax combine, no psum in the decode inner loop
        ctx.rules["kv_seq"] = [("data",), None]
    return ctx


def remat_wrap(fn, variant: Variant):
    import jax
    if variant.remat == "none":
        return fn
    if variant.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)
