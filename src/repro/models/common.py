"""Shared model machinery: param specs, initializers, norms, MLPs, losses.

Every model declares its parameters once as a pytree of ``ParamSpec`` — shape,
logical sharding axes, and initializer.  Real init, abstract (dry-run) init, and
sharding resolution all derive from that single declaration.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

# Logical axis names used across the zoo.  distributed/sharding.py maps these to
# mesh axes (with divisibility-checked fallbacks).
#   layers, vocab, embed, heads, kv_heads, head_dim, ffn, experts, expert_ffn,
#   kv_lora, rope_dim, inner (ssm d_inner), state, conv, groups, sites, audio_ctx


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _fan_in(shape: tuple[int, ...]) -> int:
    # convention: last dim is the output dim for 2D+; fan-in is the product of the
    # remaining non-layer dims.  For stacked (L, ..., out) weights the leading
    # layer dim is excluded by the caller via scale.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return max(int(jnp.prod(jnp.array(shape[:-1]))), 1)


def init_params(specs, rng: jax.Array):
    """Materialize a params pytree from specs (CPU smoke / examples only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    rngs = jax.random.split(rng, len(leaves))

    def one(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(_fan_in(spec.shape))
        if spec.init == "embed":
            std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, rngs)])


def abstract_params(specs):
    """ShapeDtypeStruct pytree (no sharding — attached later by the resolver)."""
    return spec_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def logical_axes(specs):
    return spec_map(lambda s: s.axes, specs)


# ---------------------------------------------------------------------------
# Numerics helpers (compute in bf16, normalize/softmax in f32)
# ---------------------------------------------------------------------------

def cast_compute(x, dtype=jnp.bfloat16):
    return x.astype(dtype)


def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_specs(cfg, d: int) -> dict:
    if cfg.norm == "layer":
        return {"scale": ParamSpec((d,), ("embed",), "ones"),
                "bias": ParamSpec((d,), ("embed",), "zeros")}
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def apply_norm(cfg, p: dict, x):
    if cfg.norm == "layer":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def mlp_specs(cfg, d: int, d_ff: int, prefix_axes=()) -> dict:
    pa = tuple(prefix_axes)
    pd = tuple([0] * len(pa))  # placeholder, shapes get layer dim prepended by stack
    if cfg.mlp == "swiglu":
        return {
            "w_gate": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_down": ParamSpec((d_ff, d), ("ffn", "embed")),
        }
    return {
        "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d), ("ffn", "embed")),
    }


def apply_mlp(cfg, p: dict, x):
    xc = cast_compute(x)
    if cfg.mlp == "swiglu":
        g = xc @ cast_compute(p["w_gate"])
        u = xc @ cast_compute(p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xc.dtype) * u
    else:
        u = xc @ cast_compute(p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(xc.dtype)
    return (h @ cast_compute(p["w_down"])).astype(x.dtype)


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prepend a stacked layer dim to every spec in the tree (for lax.scan)."""
    def one(s: ParamSpec) -> ParamSpec:
        scale = s.scale if s.scale is not None else 1.0 / math.sqrt(_fan_in(s.shape))
        if s.init in ("zeros", "ones"):
            scale = None
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, scale, s.dtype)
    return spec_map(one, specs)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------

def vocab_padded(cfg) -> int:
    """Vocab padded to a 256 multiple so the vocab axis always shards over the
    16-way model axis (production frameworks pad; e.g. granite's 49155 would
    otherwise replicate the logit tensor on every device).  Padded logit
    columns are masked to -1e30 before any softmax/argmax."""
    return -(-cfg.vocab_size // 256) * 256


def embed_specs(cfg) -> dict:
    vp = vocab_padded(cfg)
    out = {"embedding": ParamSpec((vp, cfg.d_model), ("vocab", "embed"), "embed")}
    if not cfg.tied_embeddings:
        out["lm_head"] = ParamSpec((cfg.d_model, vp), ("embed", "vocab"))
    return out


def embed_tokens(p: dict, tokens):
    emb = p["embedding"]
    return cast_compute(jnp.take(emb, tokens, axis=0))


def lm_logits(cfg, p: dict, h):
    """(..., D) -> (..., V_padded) f32 logits; padded columns masked."""
    hc = cast_compute(h)
    if cfg.tied_embeddings:
        w = cast_compute(p["embedding"]).T
    else:
        w = cast_compute(p["lm_head"])
    logits = (hc @ w).astype(jnp.float32)
    if cfg.logit_scale != 1.0:
        logits = logits / cfg.logit_scale
    vp = w.shape[-1]
    if vp != cfg.vocab_size:
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def chunked_softmax_xent(cfg, p: dict, h, labels, chunk: int = 512,
                         unroll: bool = False):
    """Mean token cross-entropy, scanning over sequence chunks.

    h: (B, S, D); labels: (B, S) int32.  Avoids a (B, S, V) f32 resident tensor —
    at assigned scale that tensor is hundreds of GB/device.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @jax.checkpoint  # recompute the (B, c, V) logits in the backward pass
    def piece(h_c, y_c):
        logits = lm_logits(cfg, p, h_c)                      # (B, c, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)              # (B, c)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, xs):
        h_c, y_c = xs
        return acc + piece(h_c, y_c), None

    hs = h[:, :n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    ys = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys),
                            unroll=unroll)
    if rem:
        total = total + piece(h[:, n * chunk:], labels[:, n * chunk:])
    return total / (B * S)
