"""Whisper-style encoder-decoder.

The audio frontend (conv1d stack + log-mel) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, n_audio_ctx, d_model).
Positions are sinusoidal (Whisper's learned decoder table tops out at 448 tokens;
the assigned shapes need 32k — deviation recorded in DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ParamSpec, apply_mlp, apply_norm, cast_compute,
                                 chunked_softmax_xent, embed_specs, embed_tokens,
                                 lm_logits, mlp_specs, norm_specs, stack_specs)
from repro.models.variant import BASELINE, Variant, remat_wrap


def sinusoid(S: int, D: int, offset=0):
    pos = jnp.arange(S)[:, None] + offset
    dim = jnp.arange(0, D, 2)[None, :]
    ang = pos / jnp.power(10000.0, dim / D)
    emb = jnp.zeros((S, D), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(ang))
    emb = emb.at[:, 1::2].set(jnp.cos(ang[:, : (D + 1) // 2]))
    return emb


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- parameters ----------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        enc_block = {
            "ln1": norm_specs(cfg, cfg.d_model),
            "attn": attn.gqa_specs(cfg, cfg.d_model),
            "ln2": norm_specs(cfg, cfg.d_model),
            "mlp": mlp_specs(cfg, cfg.d_model, cfg.d_ff),
        }
        dec_block = {
            "ln1": norm_specs(cfg, cfg.d_model),
            "self_attn": attn.gqa_specs(cfg, cfg.d_model),
            "ln_x": norm_specs(cfg, cfg.d_model),
            "cross_attn": attn.gqa_specs(cfg, cfg.d_model),
            "ln2": norm_specs(cfg, cfg.d_model),
            "mlp": mlp_specs(cfg, cfg.d_model, cfg.d_ff),
        }
        return {
            "embed": embed_specs(cfg),
            "enc_blocks": stack_specs(enc_block, cfg.n_encoder_layers),
            "enc_ln_f": norm_specs(cfg, cfg.d_model),
            "dec_blocks": stack_specs(dec_block, cfg.n_layers),
            "ln_f": norm_specs(cfg, cfg.d_model),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames, ctx, variant: Variant = BASELINE):
        """frames: (B, A, D) precomputed frame embeddings (frontend stub)."""
        cfg = self.cfg
        B, A, D = frames.shape
        x = cast_compute(frames) + sinusoid(A, D)[None].astype(jnp.bfloat16)
        x = ctx.constrain(x, "batch", "act_seq", None)

        def body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln1"], x)
            a = attn.gqa_attention(cfg, p["attn"], h, causal=False,
                                   kv_block=variant.kv_block, ctx=ctx,
                                   unroll=variant.unroll)
            x = x + a
            h = apply_norm(cfg, p["ln2"], x)
            return x + apply_mlp(cfg, p["mlp"], h), None

        x, _ = jax.lax.scan(remat_wrap(body, variant), x, params["enc_blocks"])
        return apply_norm(cfg, params["enc_ln_f"], x)

    # -- decoder (teacher-forced train) ----------------------------------------
    def _dec_block(self, p, x, enc_out, ctx, variant, positions):
        cfg = self.cfg
        h = apply_norm(cfg, p["ln1"], x)
        a = attn.gqa_attention(cfg, p["self_attn"], h, causal=True,
                               positions=positions, kv_block=variant.kv_block,
                               variant=variant.attn_variant, ctx=ctx,
                               unroll=variant.unroll)
        x = x + a
        h = apply_norm(cfg, p["ln_x"], x)
        # cross attention: q from decoder, k/v from encoder output
        inv = None  # whisper: no RoPE
        q, _, _ = attn.gqa_project_qkv(cfg, p["cross_attn"], h,
                                       positions, inv)
        k = jnp.einsum("bad,dhk->bahk", cast_compute(enc_out),
                       cast_compute(p["cross_attn"]["wk"]))
        v = jnp.einsum("bad,dhk->bahk", cast_compute(enc_out),
                       cast_compute(p["cross_attn"]["wv"]))
        o = attn.chunked_attention(q, k, v, causal=False,
                                   kv_block=min(variant.kv_block, k.shape[1]),
                                   ctx=ctx, unroll=variant.unroll)
        x = x + jnp.einsum("bshk,hkd->bsd", o,
                           cast_compute(p["cross_attn"]["wo"])).astype(x.dtype)
        h = apply_norm(cfg, p["ln2"], x)
        return x + apply_mlp(cfg, p["mlp"], h)

    def hidden_states(self, params, tokens, enc_out, ctx,
                      variant: Variant = BASELINE):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        x = x + sinusoid(S, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.arange(S)

        def body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            return self._dec_block(p, x, enc_out, ctx, variant, positions), None

        x, _ = jax.lax.scan(remat_wrap(body, variant), x, params["dec_blocks"])
        return apply_norm(cfg, params["ln_f"], x)

    def loss(self, params, batch, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], ctx, variant)
        h = self.hidden_states(params, batch["tokens"], enc_out, ctx, variant)
        xent = chunked_softmax_xent(cfg, params["embed"], h, batch["labels"],
                                    chunk=variant.xent_chunk,
                                    unroll=variant.unroll)
        return xent, {"xent": xent}

    # -- serving -----------------------------------------------------------------
    def cache_shapes(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        A = cfg.n_audio_ctx
        kv = cfg.n_kv_heads
        return {
            "k": ((batch, seq_len, kv, hd), ("batch", "kv_seq", "kv_heads", None),
                  jnp.bfloat16),
            "v": ((batch, seq_len, kv, hd), ("batch", "kv_seq", "kv_heads", None),
                  jnp.bfloat16),
            "xk": ((batch, A, kv, hd), ("batch", None, "kv_heads", None),
                   jnp.bfloat16),
            "xv": ((batch, A, kv, hd), ("batch", None, "kv_heads", None),
                   jnp.bfloat16),
        }

    def prefill(self, params, batch, ctx, variant: Variant = BASELINE):
        """Encode + teacher-forced decoder pass emitting self+cross caches."""
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = self.encode(params, batch["frames"], ctx, variant)
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        x = x + sinusoid(S, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.arange(S)

        def body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln1"], x)
            q, k, v = attn.gqa_project_qkv(cfg, p["self_attn"], h, positions, None)
            o = attn.chunked_attention(q, k, v, causal=True,
                                       kv_block=min(variant.kv_block, S), ctx=ctx)
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               cast_compute(p["self_attn"]["wo"])).astype(x.dtype)
            h = apply_norm(cfg, p["ln_x"], x)
            qx, _, _ = attn.gqa_project_qkv(cfg, p["cross_attn"], h, positions, None)
            xk = jnp.einsum("bad,dhk->bahk", cast_compute(enc_out),
                            cast_compute(p["cross_attn"]["wk"]))
            xv = jnp.einsum("bad,dhk->bahk", cast_compute(enc_out),
                            cast_compute(p["cross_attn"]["wv"]))
            o = attn.chunked_attention(qx, xk, xv, causal=False,
                                       kv_block=min(variant.kv_block, xk.shape[1]),
                                       ctx=ctx)
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               cast_compute(p["cross_attn"]["wo"])).astype(x.dtype)
            h = apply_norm(cfg, p["ln2"], x)
            x = x + apply_mlp(cfg, p["mlp"], h)
            entry = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
                     "xk": xk.astype(jnp.bfloat16), "xv": xv.astype(jnp.bfloat16)}
            return x, entry

        x, cache = jax.lax.scan(remat_wrap(body, variant), x, params["dec_blocks"])
        x = apply_norm(cfg, params["ln_f"], x[:, -1:, :])
        return lm_logits(cfg, params["embed"], x)[:, 0], cache

    def decode_step(self, params, cache, tokens, pos, ctx,
                    variant: Variant = BASELINE):
        cfg = self.cfg
        B = tokens.shape[0]
        x = embed_tokens(params["embed"], tokens)
        x = x + sinusoid(1, cfg.d_model, offset=pos)[None].astype(x.dtype)

        def body(x, xs):
            p, layer_cache = xs
            h = apply_norm(cfg, p["ln1"], x)
            a, ck, cv = attn.gqa_decode(cfg, p["self_attn"], h,
                                        layer_cache["k"], layer_cache["v"], pos)
            x = x + a
            h = apply_norm(cfg, p["ln_x"], x)
            positions = jnp.full((B, 1), pos, jnp.int32)
            q, _, _ = attn.gqa_project_qkv(cfg, p["cross_attn"], h, positions, None)
            o = attn.chunked_attention(q, layer_cache["xk"], layer_cache["xv"],
                                       causal=False,
                                       kv_block=min(1024, layer_cache["xk"].shape[1]))
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               cast_compute(p["cross_attn"]["wo"])).astype(x.dtype)
            h = apply_norm(cfg, p["ln2"], x)
            x = x + apply_mlp(cfg, p["mlp"], h)
            entry = {"k": ck, "v": cv,
                     "xk": layer_cache["xk"], "xv": layer_cache["xv"]}
            return x, entry

        x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        x = apply_norm(cfg, params["ln_f"], x)
        return lm_logits(cfg, params["embed"], x), new_cache
