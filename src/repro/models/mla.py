"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill use the expanded form (latent -> per-head K/V, flash-chunked).
Decode uses *weight absorption*: queries are projected into the 512-dim latent
space and attention runs directly against the compressed cache — the cache holds
only (kv_lora + rope_dim) per token, which is the whole point of MLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (apply_rope, chunked_attention,
                                    folded_causal_attention, rope_freqs)
from repro.models.common import ParamSpec, cast_compute, rms_norm


def mla_specs(cfg) -> dict:
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qdim = m.nope_head_dim + m.rope_head_dim
    return {
        "wq": ParamSpec((d, H, qdim), ("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.rope_head_dim), ("embed", "kv_lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), "ones"),
        "w_uk": ParamSpec((m.kv_lora_rank, H, m.nope_head_dim),
                          ("kv_lora", "heads", "head_dim")),
        "w_uv": ParamSpec((m.kv_lora_rank, H, m.v_head_dim),
                          ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def _project_latent(cfg, p, x, positions, inv_freq):
    """Returns (q_nope, q_rope, c_kv(normalized), k_rope) for a token block."""
    m = cfg.mla
    xc = cast_compute(x)
    q = jnp.einsum("bsd,dhk->bshk", xc, cast_compute(p["wq"]))
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, inv_freq)
    ckv = jnp.einsum("bsd,dr->bsr", xc, cast_compute(p["w_dkv"]))
    c, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, inv_freq)[:, :, 0, :]
    return q_nope, q_rope, c, k_rope


def mla_attention(cfg, p: dict, x, *, positions=None, kv_block: int = 1024,
                  variant: str = "masked", ctx=None, unroll: bool = False):
    """Expanded-form causal MLA for train/prefill.  x: (B, S, D)."""
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    inv_freq = rope_freqs(m.rope_head_dim, 1.0, cfg.rope_theta)
    q_nope, q_rope, c, k_rope = _project_latent(cfg, p, x, positions, inv_freq)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, cast_compute(p["w_uk"]))
    v = jnp.einsum("bsr,rhk->bshk", c, cast_compute(p["w_uv"]))
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    if variant == "folded" and S > kv_block and S % kv_block == 0:
        o = folded_causal_attention(q, k, v, q_block=kv_block, kv_block=kv_block,
                                    ctx=ctx, unroll=unroll)
    else:
        o = chunked_attention(q, k, v, causal=True, kv_block=min(kv_block, S),
                              ctx=ctx, unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", o, cast_compute(p["wo"])).astype(x.dtype)


def mla_decode(cfg, p: dict, x, cache_c, cache_kr, pos):
    """Absorbed-form decode against the compressed cache.

    x: (B, 1, D); cache_c: (B, Smax, R); cache_kr: (B, Smax, rope_dim).
    scores = q_nope @ W_uk . c_j  (absorb W_uk into q)  +  q_rope . k_rope_j
    out    = (attn @ c) @ W_uv @ W_o  (absorb W_uv into the output path)
    """
    m = cfg.mla
    B = x.shape[0]
    inv_freq = rope_freqs(m.rope_head_dim, 1.0, cfg.rope_theta)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _project_latent(cfg, p, x, positions, inv_freq)
    cache_c = jax.lax.dynamic_update_slice(cache_c, c_new.astype(cache_c.dtype),
                                           (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, kr_new.astype(cache_kr.dtype),
                                            (0, pos, 0))
    # absorb W_uk: q_lat (B, H, R)
    q_lat = jnp.einsum("bshk,rhk->bhr", q_nope, cast_compute(p["w_uk"]))
    s = jnp.einsum("bhr,bjr->bhj", q_lat, cast_compute(cache_c),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshk,bjk->bhj", q_rope, cast_compute(cache_kr),
                       preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(m.nope_head_dim + m.rope_head_dim))
    Smax = cache_c.shape[1]
    mask = jnp.arange(Smax)[None, None, :] <= pos
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhj,bjr->bhr", w.astype(jnp.bfloat16),
                       cast_compute(cache_c), preferred_element_type=jnp.float32)
    # absorb W_uv then W_o
    o = jnp.einsum("bhr,rhk->bhk", o_lat.astype(jnp.bfloat16), cast_compute(p["w_uv"]))
    out = jnp.einsum("bhk,hkd->bd", o, cast_compute(p["wo"]))[:, None, :]
    return out.astype(x.dtype), cache_c, cache_kr
