"""Pure Mamba2 LM (attention-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (apply_norm, cast_compute, chunked_softmax_xent,
                                 embed_specs, embed_tokens, lm_logits, norm_specs,
                                 rms_norm, stack_specs)
from repro.models.ssm import (_project, ssd_chunked, ssm_block, ssm_cache_shapes,
                              ssm_decode, ssm_dims, ssm_specs)
from repro.models.variant import BASELINE, Variant, remat_wrap


class SSMLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def param_specs(self) -> dict:
        cfg = self.cfg
        block = {"ln": norm_specs(cfg, cfg.d_model), "ssm": ssm_specs(cfg)}
        return {
            "embed": embed_specs(cfg),
            "blocks": stack_specs(block, cfg.n_layers),
            "ln_f": norm_specs(cfg, cfg.d_model),
        }

    def hidden_states(self, params, tokens, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens)
        x = ctx.constrain(x, "batch", "act_seq", None)

        def body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln"], x)
            return x + ssm_block(cfg, p["ssm"], h, ctx), None

        x, _ = jax.lax.scan(remat_wrap(body, variant), x, params["blocks"])
        return apply_norm(cfg, params["ln_f"], x)

    def loss(self, params, batch, ctx, variant: Variant = BASELINE):
        h = self.hidden_states(params, batch["tokens"], ctx, variant)
        xent = chunked_softmax_xent(self.cfg, params["embed"], h, batch["labels"],
                                    chunk=variant.xent_chunk,
                                    unroll=variant.unroll)
        return xent, {"xent": xent}

    def cache_shapes(self, batch: int, seq_len: int) -> dict:
        return ssm_cache_shapes(self.cfg, batch)

    def prefill(self, params, tokens, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        W = cfg.ssm.conv_width

        def body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln"], x)
            z, xh, Bm, Cm, dt = _project(cfg, p["ssm"], h)
            A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
            y, state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm.chunk_size)
            y = y + p["ssm"]["D"].astype(jnp.float32)[None, None, :, None] * \
                xh.astype(jnp.float32)
            d_in, H = ssm_dims(cfg)
            y = y.reshape(B, S, d_in)
            y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
            y = rms_norm(y.astype(x.dtype), p["ssm"]["gate_norm"], cfg.norm_eps)
            out = x + (cast_compute(y) @ cast_compute(p["ssm"]["w_out"])).astype(x.dtype)
            xc = cast_compute(h)
            entry = {
                "state": state,
                "conv_x": (xc @ cast_compute(p["ssm"]["w_x"]))[:, S - (W - 1):, :],
                "conv_B": (xc @ cast_compute(p["ssm"]["w_B"]))[:, S - (W - 1):, :],
                "conv_C": (xc @ cast_compute(p["ssm"]["w_C"]))[:, S - (W - 1):, :],
            }
            return out, entry

        x, cache = jax.lax.scan(remat_wrap(body, variant), x, params["blocks"])
        x = apply_norm(cfg, params["ln_f"], x[:, -1:, :])
        return lm_logits(cfg, params["embed"], x)[:, 0], cache

    def decode_step(self, params, cache, tokens, pos, ctx,
                    variant: Variant = BASELINE):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens)

        def body(x, xs):
            p, layer_cache = xs
            h = apply_norm(cfg, p["ln"], x)
            y, new_cache = ssm_decode(cfg, p["ssm"], h, layer_cache)
            return x + y, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = apply_norm(cfg, params["ln_f"], x)
        return lm_logits(cfg, params["embed"], x), new_cache
