"""Mamba2 SSD (state-space duality) block: chunked train/prefill + O(1) decode.

Chunked SSD follows Dao & Gu 2024 (ssd_minimal_discrete): intra-chunk quadratic
(MXU-friendly), inter-chunk linear recurrence via lax.scan over chunk states.
Projections are split per-stream (z/x/B/C/dt) instead of one packed matrix so that
tensor-parallel sharding (inner -> model axis) never crosses stream boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, cast_compute, rms_norm


def ssm_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads


def ssm_specs(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, H = ssm_dims(cfg)
    GN = s.n_groups * s.d_state
    return {
        "w_z": ParamSpec((d, d_in), ("embed", "inner")),
        "w_x": ParamSpec((d, d_in), ("embed", "inner")),
        "w_B": ParamSpec((d, GN), ("embed", "state")),
        "w_C": ParamSpec((d, GN), ("embed", "state")),
        "w_dt": ParamSpec((d, H), ("embed", "heads")),
        "conv_x": ParamSpec((s.conv_width, d_in), ("conv", "inner"), "normal", 0.5),
        "conv_B": ParamSpec((s.conv_width, GN), ("conv", "state"), "normal", 0.5),
        "conv_C": ParamSpec((s.conv_width, GN), ("conv", "state"), "normal", 0.5),
        "A_log": ParamSpec((H,), ("heads",), "zeros"),   # A = -exp(A_log) = -1
        "D": ParamSpec((H,), ("heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("heads",), "zeros"),
        "gate_norm": ParamSpec((d_in,), ("inner",), "ones"),
        "w_out": ParamSpec((d_in, d), ("inner", "embed")),
    }


def _causal_conv(x, w, prepend=None):
    """Depthwise causal conv.  x: (B, S, C); w: (W, C); prepend: (B, W-1, C)|None."""
    W = w.shape[0]
    if prepend is None:
        prepend = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([prepend, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    return out


def _project(cfg, p, x):
    """x: (B,S,D) -> z, xh (B,S,H,P), Bm/Cm (B,S,G,N), dt (B,S,H) [post conv+act]."""
    s = cfg.ssm
    d_in, H = ssm_dims(cfg)
    xc = cast_compute(x)
    z = xc @ cast_compute(p["w_z"])
    xs = xc @ cast_compute(p["w_x"])
    Bs = xc @ cast_compute(p["w_B"])
    Cs = xc @ cast_compute(p["w_C"])
    dt = (xc @ cast_compute(p["w_dt"])).astype(jnp.float32)
    xs = jax.nn.silu(_causal_conv(xs, cast_compute(p["conv_x"])).astype(jnp.float32)).astype(xc.dtype)
    Bs = jax.nn.silu(_causal_conv(Bs, cast_compute(p["conv_B"])).astype(jnp.float32)).astype(xc.dtype)
    Cs = jax.nn.silu(_causal_conv(Cs, cast_compute(p["conv_C"])).astype(jnp.float32)).astype(xc.dtype)
    B, S, _ = x.shape
    xh = xs.reshape(B, S, H, s.head_dim)
    Bm = Bs.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cs.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return z, xh, Bm, Cm, dt


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """SSD forward.  xh: (B,S,H,P); dt: (B,S,H) f32; A: (H,) f32 (negative);
    Bm/Cm: (B,S,G,N).  Returns y: (B,S,H,P) and final state (B,H,P,N)."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(xh.dtype)  # dt-weighted input
    dA = dt * A[None, None, :]                                       # (B,S,H) f32, <=0

    # chunk views
    xc = xdt.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, G, N)
    Cc = Cm.reshape(B, nc, Q, G, N)
    dAc = dA.reshape(B, nc, Q, H)
    cum = jnp.cumsum(dAc, axis=2)                                    # (B,nc,Q,H)

    # --- intra-chunk (quadratic, per chunk) ---
    CB = jnp.einsum("bcign,bcjgn->bcgij", cast_compute(Cc), cast_compute(Bc),
                    preferred_element_type=jnp.float32)              # (B,nc,G,Q,Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]              # (B,nc,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)  # (B,nc,Qi,Qj,H)
    CBh = jnp.repeat(CB, HG, axis=2) if G > 1 else jnp.broadcast_to(
        CB, (B, nc, H, Q, Q)) if G == 1 else CB
    M = CBh * L.transpose(0, 1, 4, 2, 3)                             # (B,nc,H,Qi,Qj)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M.astype(xc.dtype), xc,
                        preferred_element_type=jnp.float32)

    # --- chunk states ---
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)                     # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, HG, axis=3) if G > 1 else jnp.broadcast_to(
        Bc, (B, nc, Q, H, N)) if G == 1 else Bc
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn",
                        cast_compute(Bh), decay_out.astype(jnp.bfloat16),
                        xc, preferred_element_type=jnp.float32)      # (B,nc,H,P,N)

    # --- inter-chunk recurrence (serial scan over nc chunks) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                          # (B,nc,H)

    def body(h, inp):
        st, dec = inp                                                # (B,H,P,N),(B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                              # emit state *entering* chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, prev = jax.lax.scan(body, h0, (states.swapaxes(0, 1),
                                            chunk_decay.swapaxes(0, 1)))
    prev = prev.swapaxes(0, 1)                                       # (B,nc,H,P,N)

    # --- off-diagonal contribution ---
    Ch = jnp.repeat(Cc, HG, axis=3) if G > 1 else jnp.broadcast_to(
        Cc, (B, nc, Q, H, N)) if G == 1 else Cc
    decay_in = jnp.exp(cum)                                          # (B,nc,Q,H)
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp",
                       cast_compute(Ch), prev.astype(jnp.bfloat16),
                       decay_in.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, h_final


def ssm_block(cfg, p: dict, x, ctx=None):
    """Full Mamba2 block for train/prefill.  x: (B,S,D) -> (B,S,D)."""
    s = cfg.ssm
    B, S, D = x.shape
    z, xh, Bm, Cm, dt = _project(cfg, p, x)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if ctx is not None:
        xh = ctx.constrain(xh, "batch", None, "heads", None)
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk_size)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    d_in, H = ssm_dims(cfg)
    y = y.reshape(B, S, d_in)
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    return (cast_compute(y) @ cast_compute(p["w_out"])).astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------

def ssm_cache_shapes(cfg, batch: int):
    s = cfg.ssm
    d_in, H = ssm_dims(cfg)
    GN = s.n_groups * s.d_state
    W = s.conv_width
    return {
        "state": ((batch, H, s.head_dim, s.d_state), ("batch", "heads", None, None),
                  jnp.float32),
        "conv_x": ((batch, W - 1, d_in), ("batch", None, "inner"), jnp.bfloat16),
        "conv_B": ((batch, W - 1, GN), ("batch", None, "state"), jnp.bfloat16),
        "conv_C": ((batch, W - 1, GN), ("batch", None, "state"), jnp.bfloat16),
    }


def ssm_decode(cfg, p: dict, x, cache: dict):
    """x: (B,1,D); cache: dict of state/conv_x/conv_B/conv_C.  Returns (y, cache)."""
    s = cfg.ssm
    d_in, H = ssm_dims(cfg)
    B = x.shape[0]
    xc = cast_compute(x)
    z = xc @ cast_compute(p["w_z"])
    xs = xc @ cast_compute(p["w_x"])
    Bs = xc @ cast_compute(p["w_B"])
    Cs = xc @ cast_compute(p["w_C"])
    dt = (xc @ cast_compute(p["w_dt"])).astype(jnp.float32)

    def conv_step(val, w, prev):  # val (B,1,C), prev (B,W-1,C)
        window = jnp.concatenate([prev, val.astype(prev.dtype)], axis=1)  # (B,W,C)
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                         w.astype(jnp.float32))[:, None, :]
        return jax.nn.silu(out).astype(val.dtype), window[:, 1:]

    xs, conv_x = conv_step(xs, p["conv_x"], cache["conv_x"])
    Bs, conv_B = conv_step(Bs, p["conv_B"], cache["conv_B"])
    Cs, conv_C = conv_step(Cs, p["conv_C"], cache["conv_C"])

    xh = xs.reshape(B, H, s.head_dim)
    Bm = Bs.reshape(B, s.n_groups, s.d_state)
    Cm = Cs.reshape(B, s.n_groups, s.d_state)
    HG = H // s.n_groups
    Bh = jnp.repeat(Bm, HG, axis=1)                                  # (B,H,N)
    Ch = jnp.repeat(Cm, HG, axis=1)
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                                    # (B,H)

    state = cache["state"]
    state = state * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh.astype(jnp.float32), Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = (cast_compute(y) @ cast_compute(p["w_out"])).astype(x.dtype)
    new_cache = {"state": state, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
    return out, new_cache
