"""Zamba2-style hybrid: Mamba2 backbone + one *shared* transformer block.

The shared block (GQA attention + FFN, one parameter set) is applied before every
``attn_every``-th group of Mamba layers with a per-site input norm; the 54 Mamba
layers scan in groups of ``attn_every`` so the shared-block applications stay
O(sites) in the HLO while the Mamba stack stays scanned.  Zamba2's per-site LoRA
deltas are omitted (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (apply_mlp, apply_norm, chunked_softmax_xent,
                                 embed_specs, embed_tokens, lm_logits, mlp_specs,
                                 norm_specs, stack_specs)
from repro.models.ssm import (ssm_block, ssm_cache_shapes, ssm_decode, ssm_specs)
from repro.models.variant import BASELINE, Variant, remat_wrap


class HybridLM:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % cfg.attn_every == 0
        self.n_sites = cfg.n_layers // cfg.attn_every

    def param_specs(self) -> dict:
        cfg = self.cfg
        mamba_block = {"ln": norm_specs(cfg, cfg.d_model), "ssm": ssm_specs(cfg)}
        shared_block = {
            "ln1": norm_specs(cfg, cfg.d_model),
            "attn": attn.gqa_specs(cfg, cfg.d_model),
            "ln2": norm_specs(cfg, cfg.d_model),
            "mlp": mlp_specs(cfg, cfg.d_model, cfg.d_ff),
        }
        return {
            "embed": embed_specs(cfg),
            # (sites, group, ...) double-stacked mamba params
            "mamba": stack_specs(
                stack_specs(mamba_block, cfg.attn_every, "layers"),
                self.n_sites, "sites"),
            "site_norms": stack_specs(norm_specs(cfg, cfg.d_model),
                                      self.n_sites, "sites"),
            "shared": shared_block,
            "ln_f": norm_specs(cfg, cfg.d_model),
        }

    # -- shared attention block ------------------------------------------------
    def _shared_block(self, params, site_norm, x, ctx, variant, positions):
        cfg = self.cfg
        p = params["shared"]
        h = apply_norm(cfg, site_norm, x)      # per-site input norm
        h1 = apply_norm(cfg, p["ln1"], h)
        a = attn.gqa_attention(cfg, p["attn"], h1, causal=True,
                               positions=positions, kv_block=variant.kv_block,
                               variant=variant.attn_variant, ctx=ctx,
                               unroll=variant.unroll)
        h = h + a
        h2 = apply_norm(cfg, p["ln2"], h)
        return x + h + apply_mlp(cfg, p["mlp"], h2)  # residual onto the backbone

    def hidden_states(self, params, tokens, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        x = ctx.constrain(x, "batch", "act_seq", None)
        positions = jnp.arange(S)

        def mamba_body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln"], x)
            return x + ssm_block(cfg, p["ssm"], h, ctx), None

        # nested remat: the inner 6-layer scan must checkpoint its own body, or
        # the site-level recompute stacks every layer's SSD score matrices x6
        mamba_fn = remat_wrap(mamba_body, variant)

        def site_body(x, xs):
            group_p, site_norm = xs
            x = self._shared_block(params, site_norm, x, ctx, variant, positions)
            x, _ = jax.lax.scan(mamba_fn, x, group_p)
            return x, None

        x, _ = jax.lax.scan(remat_wrap(site_body, variant), x,
                            (params["mamba"], params["site_norms"]))
        return apply_norm(cfg, params["ln_f"], x)

    def loss(self, params, batch, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        h = self.hidden_states(params, batch["tokens"], ctx, variant)
        xent = chunked_softmax_xent(cfg, params["embed"], h, batch["labels"],
                                    chunk=variant.xent_chunk,
                                    unroll=variant.unroll)
        return xent, {"xent": xent}

    # -- serving -----------------------------------------------------------------
    def cache_shapes(self, batch: int, seq_len: int) -> dict:
        """Two cache families: per-mamba-layer SSM caches and per-site KV caches."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        ssm = ssm_cache_shapes(cfg, batch)
        return {
            "ssm": ssm,  # stacked (sites, group, ...) by the registry
            "k": ((batch, seq_len, cfg.n_kv_heads, hd),
                  ("batch", "kv_seq", "kv_heads", None), jnp.bfloat16),
            "v": ((batch, seq_len, cfg.n_kv_heads, hd),
                  ("batch", "kv_seq", "kv_heads", None), jnp.bfloat16),
        }

    def prefill(self, params, tokens, ctx, variant: Variant = BASELINE):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        positions = jnp.arange(S)

        def mamba_body(x, p):
            x = ctx.constrain(x, "batch", "act_seq", None)
            h = apply_norm(cfg, p["ln"], x)
            # prefill needs the final SSM state: recompute block exposing it
            from repro.models.ssm import _project, ssd_chunked, ssm_dims
            from repro.models.common import cast_compute, rms_norm
            z, xh, Bm, Cm, dt = _project(cfg, p["ssm"], h)
            A = -jnp.exp(p["ssm"]["A_log"].astype(jnp.float32))
            y, state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm.chunk_size)
            y = y + p["ssm"]["D"].astype(jnp.float32)[None, None, :, None] * \
                xh.astype(jnp.float32)
            d_in, H = ssm_dims(cfg)
            y = y.reshape(B, S, d_in)
            y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
            y = rms_norm(y.astype(x.dtype), p["ssm"]["gate_norm"], cfg.norm_eps)
            out = x + (cast_compute(y) @ cast_compute(p["ssm"]["w_out"])).astype(x.dtype)
            W = cfg.ssm.conv_width
            # conv caches: last W-1 *pre-activation* conv inputs
            xc = cast_compute(h)
            entry = {
                "state": state,
                "conv_x": (xc @ cast_compute(p["ssm"]["w_x"]))[:, S - (W - 1):, :],
                "conv_B": (xc @ cast_compute(p["ssm"]["w_B"]))[:, S - (W - 1):, :],
                "conv_C": (xc @ cast_compute(p["ssm"]["w_C"]))[:, S - (W - 1):, :],
            }
            return out, entry

        def site_body(x, xs):
            group_p, site_norm = xs
            h = apply_norm(cfg, site_norm, x)
            h1 = apply_norm(cfg, params["shared"]["ln1"], h)
            q, k, v = attn.gqa_project_qkv(
                cfg, params["shared"]["attn"], h1, positions,
                attn.rope_freqs(cfg.resolved_head_dim, cfg.rope_pct, cfg.rope_theta))
            o = attn.chunked_attention(q, k, v, causal=True,
                                       kv_block=min(variant.kv_block, S), ctx=ctx)
            from repro.models.common import cast_compute
            h = h + jnp.einsum("bshk,hkd->bsd", o,
                               cast_compute(params["shared"]["attn"]["wo"])).astype(x.dtype)
            h2 = apply_norm(cfg, params["shared"]["ln2"], h)
            x = x + h + apply_mlp(cfg, params["shared"]["mlp"], h2)
            x, ssm_cache = jax.lax.scan(remat_wrap(mamba_body, variant), x, group_p)
            entry = {"ssm": ssm_cache,
                     "k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
            return x, entry

        x, cache = jax.lax.scan(site_body, x,
                                (params["mamba"], params["site_norms"]))
        x = apply_norm(cfg, params["ln_f"], x[:, -1:, :])
        return lm_logits(cfg, params["embed"], x)[:, 0], cache

    def decode_step(self, params, cache, tokens, pos, ctx,
                    variant: Variant = BASELINE, seq_shard_decode: bool = False):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens)

        def mamba_body(x, xs):
            p, layer_cache = xs
            h = apply_norm(cfg, p["ln"], x)
            y, new_cache = ssm_decode(cfg, p["ssm"], h, layer_cache)
            return x + y, new_cache

        def site_body(x, xs):
            group_p, site_norm, layer_cache = xs
            h = apply_norm(cfg, site_norm, x)
            h1 = apply_norm(cfg, params["shared"]["ln1"], h)
            if seq_shard_decode:
                from repro.serve.flash_decode import seq_sharded_gqa_decode
                a, ck, cv = seq_sharded_gqa_decode(
                    ctx, cfg, params["shared"]["attn"], h1,
                    layer_cache["k"], layer_cache["v"], pos)
            else:
                a, ck, cv = attn.gqa_decode(cfg, params["shared"]["attn"], h1,
                                            layer_cache["k"], layer_cache["v"], pos)
            h = h + a
            h2 = apply_norm(cfg, params["shared"]["ln2"], h)
            x = x + h + apply_mlp(cfg, params["shared"]["mlp"], h2)
            x, new_ssm = jax.lax.scan(mamba_body, x,
                                      (group_p, layer_cache["ssm"]))
            return x, {"ssm": new_ssm, "k": ck, "v": cv}

        x, new_cache = jax.lax.scan(
            site_body, x, (params["mamba"], params["site_norms"], cache))
        x = apply_norm(cfg, params["ln_f"], x)
        return lm_logits(cfg, params["embed"], x), new_cache
