"""repro.istream — the instruction-stream microscope (see README.md here).

The paper's headline finding is that instruction fetch/decode width — not
cache bandwidth — throttles cache-resident workloads.  This subsystem
reproduces that *second axis* for the TPU/XLA port, OSACA-style:

    extract   parse the compiled HLO of a bench case (the Runner's cached
              compiled cases, lowered via jax.jit(...).lower().compile()),
              count loads/stores/arithmetic per pass-loop iteration, and
              compute the dependence critical path
    analyze   per-case InstructionProfile (cached beside the Runner's
              compiled-case cache, keyed by the same knob dict) +
              throughput-vs-latency bound estimates
    classify  join measured GB/s points with their instruction profiles
              (and optionally a characterize.FittedMachineModel) to label
              every point bandwidth-bound vs issue-bound with a margin

Entry points: ``python -m repro.bench istream`` (CLI),
``benchmarks/fig6_istream.py`` (the fig6 table), or::

    from repro.istream import run_istream
    report = run_istream(backends=("xla", "pallas"),
                         mixes=("copy", "rw_2to1"))
    print(report.table)
"""
from repro.istream.analyze import (InstructionProfile,  # noqa: F401
                                   ProfileCache, analyze_case, bounds,
                                   fit_issue_rate)
from repro.istream.classify import (IStreamReport, classify_points,  # noqa: F401
                                    render_fig6, run_istream,
                                    synthetic_check)
from repro.istream.extract import (HloModule, extract_profile,  # noqa: F401
                                   parse_hlo)

__all__ = ["InstructionProfile", "ProfileCache", "analyze_case", "bounds",
           "fit_issue_rate", "IStreamReport", "classify_points",
           "render_fig6", "run_istream", "synthetic_check", "HloModule",
           "extract_profile", "parse_hlo"]
