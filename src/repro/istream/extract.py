"""Compiled-IR extraction: parse optimized HLO text, weight the pass loop.

The OSACA idea ("Automatic Throughput and Critical Path Analysis ...") applied
at the level the jax toolchain exposes: we cannot see machine code, but
``jax.jit(case).lower(...).compile().as_text()`` gives the *optimized* HLO the
backend executes — fusions, while loops with trip counts, materialized
buffers.  This module is the pure-text half: a small structural parser
(computations -> instructions -> operands/attrs) plus element-weighted
counting and a dependence-critical-path walk over the measurement pass loop.

Counting conventions (the documented limits — see README.md):

* everything is weighted in *elements*, not instructions: an ``add`` over
  f32[64,128] counts 8192 arithmetic element-ops (what a fixed-width vector
  unit must issue), a scalar bookkeeping add counts 1.
* **fusions compute output-wise**: a kLoop fusion whose root is a scalar
  only evaluates the one element its root demands, however many full-shape
  intermediate instructions appear inside.  Counts inside fused computations
  are therefore *demand-weighted* — demand propagates backwards from the
  fusion root (a scalar root demands 1 element of each full-shape operand
  chain; a full root demands everything).  Region-level (while body / entry)
  instructions always execute in full and are counted at full shape.
* loads = elements read from materialized buffers: parameter/loop-state
  arrays everywhere, plus — at region level, where every instruction output
  is a buffer — reads of non-free producer results (a standalone
  reduce-window re-reading a fusion's materialized output is real traffic).
* stores = elements materialized per iteration: dynamic-update-slice updates
  (the in-place target is neither read nor re-written), fused-computation
  roots (fusion outputs are written), and region-level non-free results.
* ``dot`` counts 2*K arithmetic element-ops per output element (the
  multiply-accumulate depth of the contraction), not its operand size.
* unrecognized opcodes are counted as arithmetic (conservative: the issue
  path cannot silently shrink) but raise a loud ``UnknownOpcodeWarning``
  and land in the ``unknown`` bucket so compiler upgrades cannot quietly
  skew audit or classify results.
* the critical path uses a unit latency per element-op level, ``log2(n)``
  for reductions (tree depth), zero for free ops (tuples, bitcasts,
  reshapes) — relative chain lengths, not cycles.
"""
from __future__ import annotations

import math
import re
import warnings
from dataclasses import dataclass, field

# -- opcode categories ------------------------------------------------------

FREE_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "after-all", "opt-barrier", "partition-id",
    "replica-id",
})
REDUCE_OPS = frozenset({"reduce", "reduce-window", "dot", "convolution"})
MOVE_OPS = frozenset({
    "copy", "slice", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "pad", "reverse", "transpose", "broadcast", "gather", "scatter", "iota",
})
#: ops that consume their result elements as stores (materialized writes)
SLICING_OPS = frozenset({"slice", "dynamic-slice", "get-tuple-element"})
CONTROL_OPS = frozenset({"while", "fusion", "call", "conditional",
                         "custom-call"})
#: elementwise arithmetic the extractor recognizes explicitly — anything not
#: in one of the category sets is an *unknown* opcode (see
#: UnknownOpcodeWarning), not silently arithmetic
ARITH_OPS = frozenset({
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "sqrt",
    "rsqrt", "cbrt", "power", "maximum", "minimum", "compare", "select",
    "and", "or", "xor", "not", "convert", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "tanh", "sine", "cosine",
    "tan", "atan2", "is-finite", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "remainder", "stochastic-convert", "erf",
    "logistic", "popcnt", "count-leading-zeros", "real", "imag", "complex",
    "map", "rng", "rng-bit-generator",
})

KNOWN_OPS = FREE_OPS | REDUCE_OPS | MOVE_OPS | CONTROL_OPS | ARITH_OPS


class UnknownOpcodeWarning(UserWarning):
    """An HLO opcode outside every category set was counted as arithmetic.

    Compiler upgrades introduce opcodes; counting them silently would skew
    the audit and the bandwidth-vs-issue-bound classifier without a trace.
    The count still lands in ``arith`` (conservative — issue work cannot
    silently shrink) and is echoed in ``OpCounts.unknown``.
    """


@dataclass(frozen=True)
class HloInstr:
    name: str
    opcode: str
    elems: int                      # result elements (0 for tuple-typed)
    operands: tuple[str, ...]
    attrs: dict = field(default_factory=dict)   # calls/body/condition/...


@dataclass
class HloComputation:
    name: str
    instrs: dict[str, HloInstr]     # definition order (topological in HLO)
    root: str


@dataclass
class HloModule:
    computations: dict[str, HloComputation]
    entry: str

    def computation(self, name: str) -> HloComputation:
        return self.computations[name]


# -- parsing ----------------------------------------------------------------

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)")
_REF_RE = re.compile(r"%([\w.\-]+)")
_DIMS_RE = re.compile(r"\w+\[([\d,]*)\]")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _balanced(s: str, open_ch: str = "(", close_ch: str = ")") -> int:
    """Index one past the balanced close of ``s`` (s[0] must be open_ch)."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == open_ch:
            depth += 1
        elif ch == close_ch:
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _type_elems(type_str: str) -> int:
    """Element count of a non-tuple HLO type ('f32[64,128]{1,0}' -> 8192,
    'pred[]' -> 1); 0 for tuple types (consumers carry their own types)."""
    if type_str.startswith("("):
        return 0
    m = _DIMS_RE.search(type_str)
    if not m:
        return 1
    dims = [int(d) for d in m.group(1).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n


def _parse_rhs(rhs: str) -> tuple[str, str, tuple[str, ...], dict]:
    """'f32[] add(%a, %b), meta' -> (type, opcode, operand names, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):                     # tuple-typed result
        cut = _balanced(rhs)
        type_str, rest = rhs[:cut], rhs[cut:]
    else:
        sp = rhs.find(" ")
        type_str, rest = rhs[:sp], rhs[sp:]
    m = _OPCODE_RE.match(rest)
    opcode = m.group(1) if m else "unknown"
    rest = rest[m.end():] if m else rest
    operands: tuple[str, ...] = ()
    attr_str = rest
    paren = rest.find("(")
    if paren >= 0:
        cut = paren + _balanced(rest[paren:])
        operands = tuple(_REF_RE.findall(rest[paren:cut]))
        attr_str = rest[cut:]
    attrs: dict = {}
    for key in ("calls", "body", "condition", "to_apply"):
        m = re.search(rf"{key}=%?([\w.\-]+)", attr_str)
        if m:
            attrs[key] = m.group(1)
    m = _TRIP_RE.search(attr_str)
    if m:
        attrs["trip_count"] = int(m.group(1))
    return type_str, opcode, operands, attrs


def parse_hlo(text: str) -> HloModule:
    """Structural parse of optimized HLO text — computations, instructions,
    operand references, the handful of attrs the profiler needs."""
    computations: dict[str, HloComputation] = {}
    entry = ""
    current: HloComputation | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            current = HloComputation(name=m.group(2), instrs={}, root="")
            computations[current.name] = current
            if m.group(1):
                entry = current.name
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
        type_str, opcode, operands, attrs = _parse_rhs(rhs)
        instr = HloInstr(name=name, opcode=opcode,
                         elems=_type_elems(type_str),
                         operands=operands, attrs=attrs)
        current.instrs[name] = instr
        if is_root:
            current.root = name
    for comp in computations.values():          # root fallback: last instr
        if not comp.root and comp.instrs:
            comp.root = next(reversed(comp.instrs))
    if not entry and computations:
        entry = next(iter(computations))
    return HloModule(computations=computations, entry=entry)


# -- weighted counting ------------------------------------------------------

@dataclass
class OpCounts:
    """Element-weighted instruction counts for one computation execution."""
    loads: float = 0.0
    stores: float = 0.0
    arith: float = 0.0
    move: float = 0.0
    ops: int = 0                    # unweighted non-free HLO instructions
    opcodes: dict = field(default_factory=dict)
    unknown: dict = field(default_factory=dict)   # opcode -> element count

    def add(self, other: "OpCounts", weight: float = 1.0) -> None:
        self.loads += weight * other.loads
        self.stores += weight * other.stores
        self.arith += weight * other.arith
        self.move += weight * other.move
        self.ops += int(weight * other.ops)
        for k, v in other.opcodes.items():
            self.opcodes[k] = self.opcodes.get(k, 0) + int(weight * v)
        for k, v in other.unknown.items():
            self.unknown[k] = self.unknown.get(k, 0) + weight * v

    @property
    def issue_elems(self) -> float:
        """Total element-ops the issue/decode path must sustain."""
        return self.loads + self.stores + self.arith + self.move

    def to_dict(self) -> dict:
        return {"loads": self.loads, "stores": self.stores,
                "arith": self.arith, "move": self.move, "ops": self.ops,
                "opcodes": dict(self.opcodes),
                "unknown": dict(self.unknown)}


def _trip_count(module: HloModule, instr: HloInstr) -> int:
    """While trip count: ``known_trip_count`` when the compiler stamped it,
    else the largest integer constant in the loop condition (a
    ``compare(iv, bound)`` counted loop), else 1."""
    if "trip_count" in instr.attrs:
        return instr.attrs["trip_count"]
    cond = instr.attrs.get("condition")
    if cond and cond in module.computations:
        consts = [i.attrs["literal"]
                  for i in module.computation(cond).instrs.values()
                  if "literal" in i.attrs]
        if consts:
            return max(consts)
    return 1


def _dot_depth(comp: HloComputation, instr: HloInstr) -> float:
    """Contraction depth K of a ``dot``: (M,K) x (K,N) -> (M,N) has
    ``op0.elems * op1.elems / result.elems == K**2``."""
    if len(instr.operands) < 2 or not instr.elems:
        return 1.0
    a = comp.instrs.get(instr.operands[0])
    b = comp.instrs.get(instr.operands[1])
    if not a or not b or not a.elems or not b.elems:
        return 1.0
    k_sq = a.elems * b.elems / instr.elems
    return math.sqrt(k_sq) if k_sq > 0 else 1.0


def _operand_demand(instr: HloInstr, idx: int, src: HloInstr,
                    d: float) -> float:
    """Elements of operand ``idx`` one execution of ``instr`` touches when
    ``d`` elements of ``instr``'s result are demanded.  This single table
    drives both the backward demand propagation inside fused computations
    and the element-weighted load counting."""
    op = instr.opcode
    src_full = float(max(src.elems, 1))
    full = float(max(instr.elems, 1))
    if op in ("slice", "dynamic-slice"):
        return d if idx == 0 else 1.0
    if op == "dynamic-update-slice":
        if idx == 0:
            return 0.0              # in-place target: passed through, not read
        if idx == 1:
            return min(src_full, max(d, 1.0))
        return 1.0                  # start indices
    if op in REDUCE_OPS:            # every input element feeds the output
        return src_full * d / full
    if op == "broadcast":
        return min(src_full, d)
    if op == "concatenate":
        return src_full * d / full
    if op in CONTROL_OPS:           # fusion/call/while read via their callees
        return src_full
    if op == "tuple":
        return src_full
    return min(src_full, d)         # elementwise / reshape-like default


def _demand_map(comp: HloComputation) -> dict[str, float]:
    """Backward demand propagation from the root of a *fused* computation:
    how many elements of each instruction the fusion actually evaluates.
    kLoop fusions compute output-wise, so a scalar root demands one element
    of each full-shape chain feeding it, not the whole arrays."""
    demand: dict[str, float] = {n: 0.0 for n in comp.instrs}
    root = comp.instrs.get(comp.root)
    if root is None:
        return demand
    if root.opcode == "tuple":      # multi-output fusion: all outputs full
        for o in root.operands:
            src = comp.instrs.get(o)
            if src is not None:
                demand[o] += float(max(src.elems, 1))
    else:
        demand[comp.root] = float(max(root.elems, 1))
    # definition order is topological; reversed, every consumer is visited
    # before its operands, so demand has fully accumulated by then
    for iname in reversed(list(comp.instrs)):
        instr = comp.instrs[iname]
        cap = float(instr.elems) if instr.elems else float("inf")
        d = min(demand.get(iname, 0.0), cap)
        if d <= 0:
            continue
        for idx, o in enumerate(instr.operands):
            src = comp.instrs.get(o)
            if src is not None:
                demand[o] = demand.get(o, 0.0) \
                    + _operand_demand(instr, idx, src, d)
    return demand


def computation_counts(module: HloModule, name: str,
                       memo: dict | None = None,
                       virtual: bool = False) -> OpCounts:
    """Element-weighted counts for one execution of a computation, fusions
    inlined and nested whiles weighted by their trip counts.

    ``virtual=True`` means the computation is the body of a fusion: its
    instructions live in registers (no buffer reads/writes except params and
    the root) and are demand-weighted from the root.  ``virtual=False``
    (region/entry level) counts every instruction at full shape and treats
    every non-free result as a materialized buffer (written once, read by
    each non-free consumer)."""
    memo = {} if memo is None else memo
    key = (name, virtual)
    if key in memo:
        return memo[key]
    memo[key] = OpCounts()         # cycle guard (malformed input)
    comp = module.computation(name)
    counts = OpCounts()
    demand = _demand_map(comp) if virtual else None
    for iname, instr in comp.instrs.items():
        op = instr.opcode
        counts.opcodes[op] = counts.opcodes.get(op, 0) + 1
        full = float(max(instr.elems, 1))
        if virtual:
            cap = float(instr.elems) if instr.elems else float("inf")
            d = min(demand.get(iname, 0.0), cap)
            if d <= 0 and op not in FREE_OPS:
                continue            # dead inside the fusion: never evaluated
            d = max(d, 1.0)
        else:
            d = full
        if op in ("fusion", "call"):
            callee = instr.attrs.get("calls") or instr.attrs.get("to_apply")
            if callee and callee in module.computations:
                counts.add(computation_counts(module, callee, memo,
                                              virtual=True))
            counts.ops += 1
        elif op == "while":
            trips = _trip_count(module, instr)
            body = instr.attrs.get("body")
            cond = instr.attrs.get("condition")
            for sub in (body, cond):
                if sub and sub in module.computations:
                    counts.add(computation_counts(module, sub, memo),
                               weight=trips)
            counts.ops += 1
        elif op in FREE_OPS:
            continue
        elif op in CONTROL_OPS:     # conditional / custom-call: opaque
            counts.ops += 1
        else:
            counts.ops += 1
            if op in ("dot", "convolution"):
                counts.arith += d * 2.0 * _dot_depth(comp, instr)
            elif op in REDUCE_OPS:
                src = comp.instrs.get(instr.operands[0]) \
                    if instr.operands else None
                in_elems = src.elems if src and src.elems else full
                counts.arith += in_elems * d / full
            elif op in MOVE_OPS:
                if op == "dynamic-update-slice" and len(instr.operands) > 1:
                    upd = comp.instrs.get(instr.operands[1])
                    u = upd.elems if upd and upd.elems else 1
                    counts.move += u
                    counts.stores += u
                else:
                    counts.move += d
            elif op in ARITH_OPS:
                counts.arith += d
            else:                   # unrecognized: loud, conservative
                warnings.warn(
                    f"unrecognized HLO opcode {op!r} in computation "
                    f"{name!r}: counted as arithmetic ({d:.0f} elems)",
                    UnknownOpcodeWarning, stacklevel=2)
                counts.arith += d
                counts.unknown[op] = counts.unknown.get(op, 0.0) + d
            # loads: reads of materialized buffers — parameters and carried
            # loop state everywhere; at region level also the outputs of
            # non-free producers (every region-level result is a buffer)
            for idx, o in enumerate(instr.operands):
                src = comp.instrs.get(o)
                if src is None or src.elems <= 1:
                    continue
                is_buffer = src.opcode in ("parameter", "get-tuple-element") \
                    or (not virtual and src.opcode not in FREE_OPS)
                if is_buffer:
                    counts.loads += _operand_demand(instr, idx, src, d)
            # stores: every region-level non-free result is a written buffer
            # (dynamic-update-slice writes only its update, counted above)
            if (not virtual and instr.elems > 1
                    and op != "dynamic-update-slice"):
                counts.stores += full
    if virtual:
        # materialized root: the fusion's output buffer is written (a DUS
        # root aliases its target in place — the update is already counted)
        root = comp.instrs.get(comp.root)
        if root is not None:
            if root.opcode == "tuple":
                seen = set()
                for o in root.operands:
                    src = comp.instrs.get(o)
                    if (src and o not in seen and src.elems > 1
                            and src.opcode not in FREE_OPS
                            and src.opcode not in CONTROL_OPS
                            and src.opcode != "dynamic-update-slice"):
                        counts.stores += src.elems
                        seen.add(o)
            elif (root.opcode not in FREE_OPS
                  and root.opcode not in CONTROL_OPS
                  and root.opcode != "dynamic-update-slice"):
                counts.stores += max(root.elems, 1)
    memo[key] = counts
    return counts


# -- dependence critical path ----------------------------------------------

def _latency(module: HloModule, comp: HloComputation, instr: HloInstr,
             cp_memo: dict) -> float:
    op = instr.opcode
    if op in FREE_OPS:
        return 0.0
    if op in ("fusion", "call"):
        callee = instr.attrs.get("calls") or instr.attrs.get("to_apply")
        return critical_path(module, callee, cp_memo) \
            if callee in module.computations else 1.0
    if op == "while":
        trips = _trip_count(module, instr)
        body = instr.attrs.get("body")
        return trips * critical_path(module, body, cp_memo) \
            if body in module.computations else float(trips)
    if op in REDUCE_OPS:
        src = comp.instrs.get(instr.operands[0]) if instr.operands else None
        n = src.elems if src and src.elems else max(instr.elems, 2)
        return math.ceil(math.log2(max(n, 2)))
    return 1.0


def critical_path(module: HloModule, name: str,
                  cp_memo: dict | None = None) -> float:
    """Longest dependence chain through one execution of a computation, in
    abstract op-levels (unit per elementwise level, log2 per reduction)."""
    cp_memo = {} if cp_memo is None else cp_memo
    if name in cp_memo:
        return cp_memo[name]
    cp_memo[name] = 0.0            # cycle guard
    comp = module.computation(name)
    depth: dict[str, float] = {}
    for iname, instr in comp.instrs.items():   # definition order ~ topo order
        lat = _latency(module, comp, instr, cp_memo)
        depth[iname] = lat + max((depth[o] for o in instr.operands
                                  if o in depth), default=0.0)
    cp = max(depth.values(), default=0.0)
    cp_memo[name] = cp
    return cp


# -- the pass loop ----------------------------------------------------------

def find_pass_loop(module: HloModule, expected_trips: int | None = None
                   ) -> HloInstr | None:
    """The measurement pass loop: prefer a while in the entry computation
    whose trip count matches ``expected_trips``; else the entry while with
    the heaviest per-trip body; else the heaviest while anywhere."""
    def whiles_in(comp_name):
        return [i for i in module.computation(comp_name).instrs.values()
                if i.opcode == "while"]

    candidates = whiles_in(module.entry)
    if not candidates:
        candidates = [i for c in module.computations
                      for i in whiles_in(c) if i.opcode == "while"]
    if not candidates:
        return None
    if expected_trips is not None:
        hit = [i for i in candidates
               if _trip_count(module, i) == expected_trips]
        if hit:
            candidates = hit

    def weight(instr):
        body = instr.attrs.get("body")
        if body not in module.computations:
            return 0.0
        return computation_counts(module, body, {}).issue_elems

    return max(candidates, key=weight)


def extract_profile(hlo_text: str, expected_trips: int | None = None) -> dict:
    """Per-iteration instruction profile of the measurement pass loop in
    ``hlo_text``: element-weighted loads/stores/arith/move counts, the
    unweighted op count, the dependence critical path, and the loop trip
    count.  Falls back to whole-module counts at trips=1 when no loop is
    found (e.g. passes=1 fully unrolled away)."""
    module = parse_hlo(hlo_text)
    _attach_literals(module, hlo_text)
    loop = find_pass_loop(module, expected_trips)
    if loop is None:
        counts = computation_counts(module, module.entry)
        cp = critical_path(module, module.entry)
        return {"per_iter": counts.to_dict(), "critical_path": cp,
                "trips": 1, "loop": None}
    trips = _trip_count(module, loop)
    per_iter = OpCounts()
    cp = 0.0
    for sub in (loop.attrs.get("body"), loop.attrs.get("condition")):
        if sub and sub in module.computations:
            per_iter.add(computation_counts(module, sub, {}))
            cp = max(cp, critical_path(module, sub, {}))
    return {"per_iter": per_iter.to_dict(), "critical_path": cp,
            "trips": trips, "loop": loop.name}


_CONST_LINE_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")


def _attach_literals(module: HloModule, text: str) -> None:
    """Attach integer scalar constant literals (trip-count fallback for
    whiles the compiler didn't stamp with known_trip_count).  HloInstr is
    frozen; literals ride in a rebuilt instr's attrs."""
    literals = {m.group(1): int(m.group(2))
                for m in _CONST_LINE_RE.finditer(text)}
    if not literals:
        return
    for comp in module.computations.values():
        for name in list(comp.instrs):
            if name in literals and comp.instrs[name].opcode == "constant":
                old = comp.instrs[name]
                comp.instrs[name] = HloInstr(
                    name=old.name, opcode=old.opcode, elems=old.elems,
                    operands=old.operands,
                    attrs={**old.attrs, "literal": literals[name]})
