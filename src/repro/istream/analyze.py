"""Per-case instruction profiles: lower, extract, cache, bound.

The bridge between the Runner's compiled-case world and the extractor's
text world.  ``analyze_case`` takes the same (spec, mix, shape, dtype,
passes) coordinates the Runner caches compiled cases under, lowers the case
against abstract arguments (``backend.abstract_args`` — no working set is
ever materialized for analysis), and runs ``extract.extract_profile`` over
the optimized HLO.  Profiles are cached in a ``ProfileCache`` keyed by the
same knob dict as the Runner's case cache (``backends.case_knobs``) *minus*
passes: the per-iteration profile of the pass loop does not depend on how
many trips it runs, so one extraction covers a whole passes sweep.

``bounds`` turns a profile into the OSACA-style pair of estimates —
throughput bound (issue element-ops / issue width) vs latency bound (the
dependence critical path) — and ``fit_issue_rate`` fits the one free machine
parameter (sustained issue element-ops/second) from measured points, the way
``characterize.fit`` fits level bandwidths.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.bench.backends import case_knobs, get_backend
from repro.bench.spec import BenchSpec
from repro.istream.extract import extract_profile


@dataclass(frozen=True)
class InstructionProfile:
    """Per-pass-loop-iteration instruction profile of one compiled case."""
    mix: str
    backend: str
    shape: tuple
    dtype: str
    nbytes: int                 # working-set bytes (joins against BenchPoint)
    unroll: int
    interleave: int
    per_iter: dict              # loads/stores/arith/move/ops/opcodes
    critical_path: float        # dependence chain per iteration (op-levels)
    trips: int                  # at the passes it was extracted under
    passes: int                 # the passes it was extracted under
    loop: str | None            # HLO name of the pass loop (None = no loop)

    @property
    def issue_elems_per_iter(self) -> float:
        """Element-ops the issue path must sustain per loop iteration."""
        c = self.per_iter
        return c["loads"] + c["stores"] + c["arith"] + c["move"]

    def issue_elems_per_call(self, passes: int | None = None) -> float:
        """Element-ops per timed call: one iteration covers ``unroll``
        passes, so a call at ``passes`` runs passes/unroll iterations."""
        p = self.passes if passes is None else passes
        return self.issue_elems_per_iter * max(p // max(self.unroll, 1), 1)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shape"] = list(d["shape"])
        return d


def profile_join_key(backend: str, mix: str, unroll: int, interleave: int,
                     nbytes: int) -> tuple:
    """The coordinates shared by a BenchPoint and its profile — how
    ``classify`` joins measured throughput with extracted instructions."""
    return (backend, mix, unroll, interleave, nbytes)


def point_join_key(p) -> tuple:
    return profile_join_key(p.backend, p.mix, p.unroll, p.interleave,
                            p.nbytes)


class ProfileCache:
    """Extraction results keyed like the Runner's compiled-case cache but
    passes-free (the per-iteration profile is trip-count-invariant)."""

    def __init__(self):
        self._profiles: dict[tuple, InstructionProfile] = {}
        self.hits = 0
        self.misses = 0

    def key(self, spec: BenchSpec, mix, shape, dtype) -> tuple:
        mix_name = getattr(mix, "name", mix)
        return (spec.backend, mix_name, tuple(shape), str(dtype),
                case_knobs(spec))

    def get(self, spec, mix, shape, dtype) -> InstructionProfile | None:
        prof = self._profiles.get(self.key(spec, mix, shape, dtype))
        if prof is not None:
            self.hits += 1
        return prof

    def put(self, spec, mix, shape, dtype,
            prof: InstructionProfile) -> InstructionProfile:
        self.misses += 1
        self._profiles[self.key(spec, mix, shape, dtype)] = prof
        return prof

    def __len__(self) -> int:
        return len(self._profiles)


def lower_case(spec: BenchSpec, mix_name: str, shape, dtype, passes: int,
               runner=None) -> str:
    """Optimized compiled-HLO text of one bench case — the shared lowering
    step under ``analyze_case`` and ``repro.audit`` (golden generation).

    Reuses ``runner``'s compiled-case cache when given (the case the Runner
    timed IS the case analyzed — no second trace); otherwise compiles fresh.
    The lowering uses ``backend.abstract_args`` so no working-set buffer is
    built.  Requires a make_case-style backend (xla / pallas); the mesh
    backends shard the same oracles and are not separately profiled.
    """
    import jax
    import jax.numpy as jnp
    from repro.bench.mixes import get_mix

    backend = get_backend(spec.backend)
    if not hasattr(backend, "abstract_args"):
        raise TypeError(f"backend {spec.backend!r} exposes no abstract_args; "
                        f"istream analyzes the xla/pallas case backends")
    mix = get_mix(mix_name)
    dtype = jnp.dtype(dtype)
    case = (runner._case(backend, spec, mix, shape, dtype, passes)
            if runner is not None
            else backend.make_case(spec, mix, shape, dtype, passes))
    args = backend.abstract_args(spec, mix, shape, dtype)
    return jax.jit(case).lower(*args).compile().as_text()


def profile_from_hlo(hlo: str, spec: BenchSpec, mix_name: str, shape, dtype,
                     passes: int) -> InstructionProfile:
    """Extract + package: compiled-HLO text -> InstructionProfile (the
    deviceless half of ``analyze_case``, shared with the audit goldens)."""
    import jax.numpy as jnp
    from repro.bench.mixes import get_mix

    mix = get_mix(mix_name)
    dtype = jnp.dtype(dtype)
    expected_trips = max(passes // max(spec.unroll, 1), 1)
    raw = extract_profile(hlo, expected_trips=expected_trips)
    n_elems = 1
    for d in shape:
        n_elems *= d
    return InstructionProfile(
        mix=mix.name, backend=spec.backend, shape=tuple(shape),
        dtype=str(dtype), nbytes=n_elems * dtype.itemsize,
        unroll=spec.unroll, interleave=spec.interleave,
        per_iter=raw["per_iter"], critical_path=raw["critical_path"],
        trips=raw["trips"], passes=passes, loop=raw["loop"])


def analyze_case(spec: BenchSpec, mix_name: str, shape, dtype, passes: int,
                 runner=None, cache: ProfileCache | None = None
                 ) -> InstructionProfile:
    """Extract the instruction profile of one compiled bench case
    (``lower_case`` -> ``profile_from_hlo``, with profile caching)."""
    import jax.numpy as jnp
    from repro.bench.mixes import get_mix

    mix = get_mix(mix_name)
    dtype = jnp.dtype(dtype)
    if cache is not None:
        prof = cache.get(spec, mix, shape, dtype)
        if prof is not None:
            if prof.passes != passes:    # same body, different trip count
                prof = dataclasses.replace(
                    prof, passes=passes,
                    trips=max(passes // max(spec.unroll, 1), 1))
            return prof

    hlo = lower_case(spec, mix_name, shape, dtype, passes, runner=runner)
    prof = profile_from_hlo(hlo, spec, mix_name, shape, dtype, passes)
    if cache is not None:
        cache.put(spec, mix, shape, dtype, prof)
    return prof


def bounds(profile: InstructionProfile, issue_width: float = 8.0) -> dict:
    """OSACA-style per-iteration bound pair: the throughput bound is the
    issue element-ops divided by the machine's issue width (how long a
    width-``issue_width`` issue path needs, in op-levels), the latency bound
    is the dependence critical path.  The larger one names the regime the
    *compiled code shape* predicts — before any measurement."""
    tp = profile.issue_elems_per_iter / max(issue_width, 1e-12)
    lat = profile.critical_path
    return {"throughput_bound": tp, "latency_bound": lat,
            "bound": "throughput" if tp >= lat else "latency"}


def fit_issue_rate(pairs) -> float:
    """Fit the sustained issue rate (element-ops/second) from measured
    (BenchPoint, InstructionProfile) pairs: the fastest point sets the
    demonstrated capability, exactly like a measured-bandwidth fit takes the
    best sustained GB/s.  Returns 0.0 when nothing is fittable."""
    rates = [prof.issue_elems_per_call(p.passes) / p.mean_s
             for p, prof in pairs
             if prof is not None and p.mean_s > 0]
    return max(rates, default=0.0)
