"""Bandwidth-bound vs issue-bound classification of measured points.

The paper's claim is a *per-point* statement: a cache-resident working set
is throttled by instruction issue, a DRAM-resident one by bandwidth.  This
module joins each measured BenchPoint with its extracted InstructionProfile
and computes the two candidate time estimates for one timed call:

    mem_time   = bytes_per_call / achievable_bandwidth(nbytes)
    issue_time = issue_elems_per_call / fitted_issue_rate

whichever is larger names the regime; the confidence margin is
``|log2(issue_time / mem_time)|`` — 0 means the estimates tie (the label is
a coin flip), 1 means one is 2x the other.  Achievable bandwidth comes from
a ``characterize.FittedMachineModel`` when one is supplied (the level whose
capacity holds the working set), else from the best measured GB/s at the
same size in the result itself (self-calibration: the fastest mix at a size
approximates what the hierarchy can move).

``run_istream`` is the subsystem driver: sweep unroll x interleave over the
requested mixes and backends (one Runner, shared compiled-case cache),
extract every case's profile, classify, and render the fig6 table.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field

from repro.bench.result import BenchResult
from repro.bench.spec import BenchSpec
from repro.istream.analyze import (InstructionProfile, ProfileCache,
                                   analyze_case, fit_issue_rate,
                                   point_join_key, profile_join_key)

#: label strings — the only two values a point's istream["label"] takes
BANDWIDTH_BOUND = "bandwidth-bound"
ISSUE_BOUND = "issue-bound"


def _bandwidth_for(nbytes: int, result: BenchResult, model=None) -> float:
    """Achievable bandwidth (B/s) for a working set of ``nbytes``: the
    fitted model's level bandwidth when a model is given, else the best
    measured GB/s at this size in the result (self-calibration)."""
    if model is not None and getattr(model, "levels", ()):
        for lvl in model.levels:
            cap = lvl.capacity_bytes
            if (cap is None or nbytes <= cap) and lvl.bandwidth:
                return lvl.best_gbps * 1e9
        last = model.levels[-1]
        if last.bandwidth:
            return last.best_gbps * 1e9
    best = max((p.gbps for p in result.points if p.nbytes == nbytes),
               default=0.0)
    return best * 1e9


def classify_points(result: BenchResult, profiles: dict,
                    issue_rate: float | None = None, model=None
                    ) -> BenchResult:
    """Annotate every point that has a profile with its regime label.

    ``profiles`` maps ``profile_join_key(...)`` -> InstructionProfile.
    ``issue_rate`` (element-ops/s) is fitted from the joined points when not
    given.  Returns a NEW BenchResult (points are frozen; annotated copies
    replace them) with ``meta["istream"]`` recording the fit and the label
    census; unjoined points pass through with ``istream=None``.

    Each annotation also records the point's *traffic provenance* from the
    accounting auditor: ``istream["traffic"]`` is ``"audited"`` when
    ``repro.audit`` holds an enforced compiled-traffic expectation for the
    (mix, backend, knobs) combination — its GB/s is absolute — and
    ``"waived"`` (with ``istream["traffic_waiver"]`` naming the caveat)
    when the combination carries a documented waiver and the number should
    be read as shape-only.  Since the rotating-carry fix, carried-mix
    unroll>1 points are audited, not waived.
    """
    import numpy as np
    from repro.audit.verify import expected_counts, waiver_reason
    from repro.bench.mixes import get_mix

    def _traffic_status(p):
        knobs = {"unroll": p.unroll, "interleave": p.interleave,
                 "streams": p.streams, "block_rows": p.block_rows}
        try:
            mixdef = get_mix(p.mix)
        except KeyError:
            return None, None
        n = p.nbytes / np.dtype(p.dtype).itemsize
        if expected_counts(mixdef, p.backend, n, knobs) is not None:
            return "audited", None
        return "waived", (waiver_reason(mixdef, p.backend, knobs)
                          or "no expectation for this backend")

    pairs = [(p, profiles.get(point_join_key(p))) for p in result.points]
    if issue_rate is None and model is not None:
        # schema-v2 fitted models carry the issue fit (characterize.fit)
        issue_rate = (getattr(model, "issue", None) or {}
                      ).get("rate_elems_per_s")
    if issue_rate is None:
        issue_rate = fit_issue_rate(pairs)
    points = []
    census = {BANDWIDTH_BOUND: 0, ISSUE_BOUND: 0}
    for p, prof in pairs:
        if prof is None or issue_rate <= 0 or p.mean_s <= 0:
            points.append(p)
            continue
        bw = _bandwidth_for(p.nbytes, result, model)
        mem_time = p.bytes_per_call / bw if bw > 0 else float("inf")
        issue_time = prof.issue_elems_per_call(p.passes) / issue_rate
        label = ISSUE_BOUND if issue_time > mem_time else BANDWIDTH_BOUND
        if mem_time > 0 and issue_time > 0 and math.isfinite(mem_time):
            margin = abs(math.log2(issue_time / mem_time))
        else:
            margin = float("inf")
        census[label] += 1
        traffic, waiver = _traffic_status(p)
        points.append(dataclasses.replace(p, istream={
            "label": label,
            "traffic": traffic,
            "traffic_waiver": waiver,
            "margin": margin if math.isfinite(margin) else None,
            "issue_time_s": issue_time,
            "mem_time_s": mem_time if math.isfinite(mem_time) else None,
            "issue_elems_per_call": prof.issue_elems_per_call(p.passes),
            "critical_path": prof.critical_path,
            "trips": prof.trips,
            "per_iter": dict(prof.per_iter)}))
    out = BenchResult(points=points, spec=result.spec,
                      machine=result.machine, meta=dict(result.meta),
                      schema_version=result.schema_version)
    out.meta["istream"] = {"issue_rate_elems_per_s": issue_rate,
                           "labels": census,
                           "model": getattr(model, "name", None)}
    return out


def render_fig6(result: BenchResult) -> str:
    """The fig6 table: every classified point with its knobs, throughput,
    regime label, confidence margin, and traffic provenance (markdown).

    GB/s in ``audited`` rows is absolute — the auditor enforces that the
    compiled code moves the declared bytes, including carried mixes at
    unroll>1 (rotating-carry fix).  ``waived`` rows carry a documented
    accounting caveat (e.g. chunked interleave) and should be read as
    issue-axis shapes, not absolute throughput."""
    lines = ["| backend | mix | KiB | unroll | ilv | GB/s | label | "
             "margin | traffic |",
             "|---|---|---:|---:|---:|---:|---|---:|---|"]
    for p in result.points:
        info = p.istream
        if info is None:
            continue
        margin = info.get("margin")
        lines.append(
            f"| {p.backend} | {p.mix} | {p.nbytes / 1024:.0f} "
            f"| {p.unroll} | {p.interleave} | {p.gbps:.2f} "
            f"| {info['label']} "
            f"| {'inf' if margin is None else f'{margin:.2f}'} "
            f"| {info.get('traffic') or '-'} |")
    meta = result.meta.get("istream", {})
    rate = meta.get("issue_rate_elems_per_s")
    if rate:
        lines.append("")
        lines.append(f"fitted issue rate: {rate:.3e} element-ops/s; "
                     f"labels: {meta.get('labels')}")
    return "\n".join(lines)


@dataclass
class IStreamReport:
    """Everything ``run_istream`` produced: the annotated result, the fitted
    issue rate, the per-case profiles (by join key), and the fig6 table."""
    result: BenchResult
    issue_rate: float
    profiles: dict = field(default_factory=dict)
    table: str = ""

    @property
    def labels(self) -> dict:
        return self.result.meta.get("istream", {}).get("labels", {})


def synthetic_check() -> dict:
    """Deterministic classifier self-test on synthetic profiles — no jax,
    no timing.  Two hand-built cases: a cache-resident case whose issue work
    dwarfs its byte traffic (must classify issue-bound) and a DRAM-sized
    case whose bytes dwarf its issue work (must classify bandwidth-bound).
    CI's fast-fail step asserts both labels appear.  Returns the census."""
    from repro.bench.result import BenchPoint

    def _point(nbytes, bpc, mean_s, gbps, mix):
        return BenchPoint(
            nbytes=nbytes, mix=mix, dtype="float32", backend="synthetic",
            passes=8, streams=1, block_rows=None, reps=3,
            bytes_per_call=bpc, flops_per_call=0.0, mean_s=mean_s,
            std_s=0.0, min_s=mean_s, gbps=gbps, gflops=0.0)

    def _profile(mix, nbytes, loads, stores, arith):
        return InstructionProfile(
            mix=mix, backend="synthetic", shape=(nbytes // 512, 128),
            dtype="float32", nbytes=nbytes, unroll=1, interleave=1,
            per_iter={"loads": loads, "stores": stores, "arith": arith,
                      "move": 0.0, "ops": 4, "opcodes": {}},
            critical_path=16.0, trips=8, passes=8, loop="while.0")

    # issue-heavy: 32 KiB set, tiny bytes/call, huge arithmetic per iter —
    # slow despite sitting in cache.  bandwidth-heavy: 256 MiB set, huge
    # bytes/call, light issue work.  load_sum is the unprofiled reference
    # that reveals the achievable cache bandwidth at the small size (the
    # self-calibration path: without it, fma's own throughput would define
    # "achievable" and the classifier could only ever tie).
    small, big = 32 * 2**10, 256 * 2**20
    points = [_point(small, bpc=8 * small, mean_s=1e-3, gbps=0.26,
                     mix="fma"),
              _point(small, bpc=8 * small, mean_s=6.55e-6, gbps=40.0,
                     mix="load_sum"),
              _point(big, bpc=8 * big, mean_s=1e-1, gbps=21.5,
                     mix="copy")]
    profiles = {
        profile_join_key("synthetic", "fma", 1, 1, small):
            _profile("fma", small, loads=8e3, stores=8e3, arith=5e6),
        profile_join_key("synthetic", "copy", 1, 1, big):
            _profile("copy", big, loads=6e7, stores=6e7, arith=1e3),
    }
    res = BenchResult(points=points)
    out = classify_points(res, profiles)
    labels = {p.mix: p.istream["label"] for p in out.points
              if p.istream is not None}
    ok = (labels.get("fma") == ISSUE_BOUND
          and labels.get("copy") == BANDWIDTH_BOUND)
    return {"ok": ok, "labels": labels,
            "census": out.meta["istream"]["labels"],
            "issue_rate": out.meta["istream"]["issue_rate_elems_per_s"]}


def run_istream(backends=("xla", "pallas"), mixes=("copy", "rw_2to1"),
                sizes=None, unrolls=(1, 2), interleaves=(1, 2),
                reps: int = 3, smoke: bool = False, model=None,
                runner=None) -> IStreamReport:
    """The subsystem driver: sweep unroll x interleave per backend over the
    given mixes and sizes, extract each case's compiled-IR profile, fit the
    issue rate, classify every point, and render the fig6 table.

    One Runner serves the whole sweep, so a knob that does not change
    compilation re-times a cached case, and analysis lowers the *same*
    cached case objects the timing used.  ``smoke`` shrinks sizes/reps to a
    seconds-scale end-to-end pass (CI's fast-fail gate).
    """
    from repro.bench.runner import Runner, pick_passes
    from repro.core import buffers
    import jax.numpy as jnp

    if sizes is None:
        sizes = (1 << 16, 1 << 20) if smoke else (1 << 16, 1 << 20, 1 << 24)
    if smoke:
        reps = min(reps, 2)
    runner = runner or Runner()
    specs = [BenchSpec(mixes=tuple(mixes), sizes=tuple(sizes),
                       backend=b, unroll=u, interleave=i, reps=reps)
             for b in backends
             for u in unrolls
             for i in interleaves]
    result = runner.run_many(specs, extra_meta={"sweep": "istream"})

    cache = ProfileCache()
    profiles: dict[tuple, InstructionProfile] = {}
    dtype = jnp.dtype(specs[0].dtype)
    for spec in specs:
        for nbytes in spec.sizes:
            shape = buffers.working_set_shape(nbytes, dtype=dtype)
            real_bytes = shape[0] * shape[1] * dtype.itemsize
            passes = spec.passes or pick_passes(real_bytes,
                                               spec.target_bytes)
            if passes % spec.unroll:    # mirror the Runner's round-up
                passes += spec.unroll - passes % spec.unroll
            for mix_name in spec.mixes:
                prof = analyze_case(spec, mix_name, shape, dtype, passes,
                                    runner=runner, cache=cache)
                profiles[profile_join_key(spec.backend, mix_name,
                                          spec.unroll, spec.interleave,
                                          real_bytes)] = prof
    annotated = classify_points(result, profiles, model=model)
    rate = annotated.meta["istream"]["issue_rate_elems_per_s"]
    return IStreamReport(result=annotated, issue_rate=rate,
                         profiles=profiles, table=render_fig6(annotated))
