"""repro.bench — the unified experiment API (see README.md in this package).

One declarative BenchSpec, pluggable backends (xla oracles / pallas TPU
kernels), one Runner owning the measurement discipline, versioned results:

    from repro.bench import BenchSpec, Runner
    res = Runner().run(BenchSpec(mixes=("load_sum", "fma_8"),
                                 sizes=(32 * 2**10, 16 * 2**20)))
    res.to_json("sweep.json")

CLI: ``python -m repro.bench {run,list-mixes,compare,launch}`` — ``launch``
spawns N coordinated local processes (the ``distributed`` backend's
single-machine multi-host simulation; see bench.distributed).

Heavy submodules (backends pull in the kernel packages) load lazily so that
``repro.core`` modules can import the mix registry without a cycle.
"""
from repro.bench.mixes import (FMA_DEPTHS, MAX_RW, MixDef,  # noqa: F401
                               RW_RATIOS, get_mix, mix_names, registry,
                               rw_name, rw_ratio)
from repro.bench.result import (BenchPoint, BenchResult,  # noqa: F401
                                SCHEMA_VERSION, machine_meta)
from repro.bench.spec import (BenchSpec, BenchSpecError,  # noqa: F401
                              SPEC_VERSION, quick_spec)

_LAZY = {
    "Runner": ("repro.bench.runner", "Runner"),
    "run": ("repro.bench.runner", "run"),
    "pick_passes": ("repro.bench.runner", "pick_passes"),
    "Backend": ("repro.bench.backends", "Backend"),
    "get_backend": ("repro.bench.backends", "get_backend"),
    "register_backend": ("repro.bench.backends", "register_backend"),
    "available_backends": ("repro.bench.backends", "available_backends"),
    # multi-process coordination (the `distributed` backend's plumbing)
    "ensure_initialized": ("repro.bench.distributed", "ensure_initialized"),
    "gather_result": ("repro.bench.distributed", "gather_result"),
    "launch_local": ("repro.bench.distributed", "launch_local"),
}

__all__ = ["BenchSpec", "BenchSpecError", "BenchPoint", "BenchResult",
           "MixDef", "FMA_DEPTHS", "MAX_RW", "RW_RATIOS", "SCHEMA_VERSION",
           "SPEC_VERSION", "registry", "get_mix", "mix_names", "rw_name",
           "rw_ratio", "machine_meta", "quick_spec", *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module 'repro.bench' has no attribute {name!r}")
