"""BenchSpec — one benchmark point-set as a frozen, serializable declaration.

The paper treats every measurement as the product of (instruction mix x
working-set size x access pattern x repetition discipline).  A BenchSpec *is*
that product: a validated, hashable, JSON-round-trippable configuration that
the Runner executes on any registered backend.  Knob -> paper mapping:

    sizes        C1  working-set sweep across the memory hierarchy
    mixes        C2  instruction-mix ladder (see repro.bench.mixes; incl. the
                 parameterized rw_RtoW read/write-ratio family — validation
                 resolves family members through the registry's get_mix, so a
                 bad R:W surfaces as BenchSpecError before any timing)
    streams      C3  interleaved address streams (addressing-mode overhead)
    block_rows   C4  rows per load step (LD1D/LD2D/LD4D analogue)
    devices      Fig 4  working set spread over the first k mesh devices
                 (multi-device backends only, e.g. ``sharded``)
    unroll       §5  per-pass unroll factor: the measurement loop body holds
                 ``unroll`` chained sweeps per trip (fewer loop-control ops
                 per byte moved — the decode/issue-width probe)
    interleave   §5  independent dependence chains per sweep: the working set
                 is split into ``interleave`` row chunks, each with its own
                 accumulator, combined only after the sweep (shortens the
                 dependence critical path without changing bytes/flops)
    load         Mess-style loaded latency: number of bandwidth-generator
                 streams co-scheduled with a ``latency_chase`` probe in ONE
                 timed composite (0 = idle probe).  Requires every mix in
                 the spec to be a chase mix; on the mesh backends the probe
                 runs on shard 0 and each generator on its own sibling
                 shard, so ``devices`` must equal ``load + 1``
    reps/warmup/passes   the serialized-timing repetition discipline (§4/§5)

``unroll`` and ``interleave`` feed ``repro.istream``: they vary issue
pressure and ILP at *constant* accounting, so the instruction-stream
classifier can separate bandwidth-bound from issue-bound points.

spec_version history: 1 = original knob set; 2 = adds ``devices`` (older
files load with the single-device default); 3 = adds ``unroll`` /
``interleave`` (the instruction-stream knobs; older files load with 1/1);
4 = adds ``load`` (co-scheduled bandwidth generators for loaded-latency
composites; older files load with the idle default 0).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from pathlib import Path

from repro.bench import mixes as mixreg

SPEC_VERSION = 4


class BenchSpecError(ValueError):
    """Invalid BenchSpec field or unsupported knob/backend combination."""


def knob_names() -> tuple[str, ...]:
    """Every valid BenchSpec field name, sorted — error messages list these
    so an unknown/invalid knob is decodable without opening this file."""
    return tuple(sorted(f.name for f in dataclasses.fields(BenchSpec)))


@dataclass(frozen=True)
class BenchSpec:
    """Declarative benchmark configuration (frozen; use ``.replace()``)."""
    mixes: tuple[str, ...] = ("load_sum",)
    sizes: tuple[int, ...] = (32 * 2**10, 1 * 2**20, 16 * 2**20)
    dtype: str = "float32"
    backend: str = "xla"
    block_rows: int | None = None     # None = backend default tiling
    streams: int = 1
    devices: int = 1                  # mesh devices (multi-device backends)
    unroll: int = 1                   # sweeps per measurement-loop trip
    interleave: int = 1               # independent dependence chains / sweep
    load: int = 0                     # co-scheduled bandwidth generators
    passes: int | None = None         # None = auto from target_bytes
    target_bytes: float = 2e8         # auto pass-picking: bytes per timed call
    reps: int = 10
    warmup: int = 2
    value: float = 1.234567           # buffer init value (denormal-avoiding)
    interpret: bool = True            # Pallas interpret mode (False on TPU)
    tags: tuple[str, ...] = ()        # free-form labels carried into results

    # -- validation ---------------------------------------------------------
    def __post_init__(self):
        # coerce lists (e.g. from JSON) to tuples so the spec stays hashable
        for f in ("mixes", "sizes", "tags"):
            v = getattr(self, f)
            if isinstance(v, list):
                object.__setattr__(self, f, tuple(v))
        self.validate()

    def validate(self) -> None:
        # late import: backends.py imports this module for BenchSpecError
        from repro.bench.backends import get_backend
        try:
            backend = get_backend(self.backend)
        except KeyError as e:
            raise BenchSpecError(str(e)) from None
        if not self.mixes:
            raise BenchSpecError("spec needs at least one mix")
        for m in self.mixes:
            try:
                mix = mixreg.get_mix(m)
            except KeyError as e:
                raise BenchSpecError(str(e)) from None
            if not backend.supports(mix):
                raise BenchSpecError(
                    f"mix {m!r} is not supported by backend "
                    f"{self.backend!r} (declared: {mix.backends})")
            if self.load > 0 and not mix.chase:
                raise BenchSpecError(
                    f"load={self.load} co-schedules bandwidth generators "
                    f"around a latency probe, so every mix must be a chase "
                    f"mix (e.g. 'latency_chase'); got {m!r}")
        if not self.sizes or any(int(s) <= 0 for s in self.sizes):
            raise BenchSpecError(f"sizes must be positive ints: {self.sizes}")
        if self.streams < 1:
            raise BenchSpecError(f"streams must be >= 1: {self.streams}")
        if self.devices < 1:
            raise BenchSpecError(f"devices must be >= 1: {self.devices}")
        if self.devices > 1 and not getattr(backend, "multi_device", False):
            raise BenchSpecError(
                f"backend {self.backend!r} runs on a single device; "
                f"devices={self.devices} needs a multi-device backend "
                f"(e.g. 'sharded')")
        if self.block_rows is not None and (
                self.block_rows < 1 or self.block_rows % 8):
            raise BenchSpecError(
                f"block_rows must be a positive multiple of 8 (the f32 "
                f"sublane tile): {self.block_rows}")
        if self.unroll < 1:
            raise BenchSpecError(f"unroll must be >= 1: {self.unroll}")
        if self.interleave < 1:
            raise BenchSpecError(
                f"interleave must be >= 1: {self.interleave}")
        if self.load < 0:
            raise BenchSpecError(f"load must be >= 0: {self.load}")
        if self.passes is not None and self.passes < 1:
            raise BenchSpecError(f"passes must be >= 1: {self.passes}")
        if self.passes is not None and self.passes % self.unroll:
            raise BenchSpecError(
                f"passes={self.passes} must be a multiple of "
                f"unroll={self.unroll} (the measurement loop runs whole "
                f"unrolled bodies); drop passes to let the Runner round up")
        if self.reps < 1 or self.warmup < 0:
            raise BenchSpecError(
                f"need reps >= 1, warmup >= 0: {self.reps}, {self.warmup}")
        if self.target_bytes <= 0:
            raise BenchSpecError(f"target_bytes must be > 0: {self.target_bytes}")
        import jax.numpy as jnp
        try:
            jnp.dtype(self.dtype)
        except TypeError as e:
            raise BenchSpecError(f"bad dtype {self.dtype!r}: {e}") from None

    # -- convenience --------------------------------------------------------
    def replace(self, **kw) -> "BenchSpec":
        return dataclasses.replace(self, **kw)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for f in ("mixes", "sizes", "tags"):   # JSON-canonical (round-trips)
            d[f] = list(d[f])
        d["spec_version"] = SPEC_VERSION
        return d

    def to_json(self, path: str | Path | None = None) -> str:
        s = json.dumps(self.to_dict(), indent=2, sort_keys=True)
        if path is not None:
            Path(path).write_text(s)
        return s

    @classmethod
    def from_dict(cls, d: dict) -> "BenchSpec":
        d = dict(d)
        ver = d.pop("spec_version", SPEC_VERSION)
        if ver > SPEC_VERSION:
            raise BenchSpecError(
                f"spec_version {ver} is newer than supported {SPEC_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise BenchSpecError(
                f"unknown spec fields: {sorted(unknown)}; valid fields: "
                f"{list(knob_names())}")
        return cls(**d)

    @classmethod
    def from_json(cls, src: str | Path) -> "BenchSpec":
        """Accepts a Path, a path string, or an inline JSON object string
        (anything starting with '{'); a mistyped path raises
        FileNotFoundError rather than a JSON parse error."""
        if isinstance(src, Path):
            text = src.read_text()
        else:
            s = str(src)
            text = s if s.lstrip().startswith("{") else Path(s).read_text()
        return cls.from_dict(json.loads(text))


def quick_spec(backend: str = "xla", **kw) -> BenchSpec:
    """The --quick preset: small sizes, few reps, light pass target."""
    base = dict(mixes=("load_sum", "copy", "fma_8"),
                sizes=(32 * 2**10, 256 * 2**10, 2 * 2**20),
                reps=3, warmup=1, target_bytes=2e7, backend=backend)
    base.update(kw)
    return BenchSpec(**base)
