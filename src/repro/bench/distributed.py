"""Multi-process coordination for the ``distributed`` backend (paper Fig 4
at multi-host scale).

Three concerns live here, deliberately separated from the backend itself
(``bench.backends.DistributedBackend`` — kernels and mesh placement):

* **initialization** — ``ensure_initialized()`` wraps
  ``jax.distributed.initialize`` with env-var autodetection
  (``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``,
  falling back to JAX's own ``JAX_COORDINATOR_ADDRESS`` etc.), enabling the
  gloo CPU-collective implementation first so forced-host-device simulation
  works on a laptop/CI exactly like a real multi-host mesh.  It MUST run
  before anything initializes the jax backend (i.e. before ``jax.devices()``
  is first called) — the CLI and the figure scripts call it up front.
* **gathering** — ``gather_result()`` allgathers every process's per-point
  timings (``multihost_utils.process_allgather``) and merges them into ONE
  BenchResult: each merged point takes the *slowest* process's timing triple
  (aggregate bandwidth = global bytes / the wall time of the straggler), the
  per-process means land in ``meta["per_process_mean_s"]`` for skew
  inspection, and the machine meta records ``process_count`` and the
  per-host device counts (result schema v3).
* **launching** — ``launch_local()`` spawns N coordinated local processes
  with ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` each, so a
  single machine simulates an N-host mesh with N*K global devices; this is
  the CI-testable path behind ``python -m repro.bench launch`` and
  ``scripts/launch_distributed.py``.  On a real cluster you skip the
  launcher: start one process per host with the env vars set and the same
  ``run --backend distributed`` command.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import threading
import time

from repro.obs import metrics, trace

#: env vars read by ``env_info`` (REPRO_* first, then JAX's own names)
ENV_COORDINATOR = ("REPRO_COORDINATOR", "JAX_COORDINATOR_ADDRESS")
ENV_NUM_PROCESSES = ("REPRO_NUM_PROCESSES", "JAX_NUM_PROCESSES")
ENV_PROCESS_ID = ("REPRO_PROCESS_ID", "JAX_PROCESS_ID")

_initialized = False


def _env(names, cast=str):
    for n in names:
        v = os.environ.get(n)
        if v not in (None, ""):
            return cast(v)
    return None


def env_info() -> tuple[str | None, int | None, int | None]:
    """(coordinator_address, num_processes, process_id) from the environment;
    None where unset.  The launcher sets the REPRO_* triple on every child."""
    return (_env(ENV_COORDINATOR),
            _env(ENV_NUM_PROCESSES, int),
            _env(ENV_PROCESS_ID, int))


def env_active() -> bool:
    """True when this process was started under a multi-process launcher."""
    coord, nproc, _ = env_info()
    return coord is not None and (nproc or 1) > 1


def is_initialized() -> bool:
    if _initialized:
        return True
    try:    # already initialized by someone else (e.g. a framework harness)
        from jax._src import distributed as _dist
        return _dist.global_state.client is not None
    except Exception:
        return False


def initialize(coordinator_address: str, num_processes: int,
               process_id: int) -> None:
    """``jax.distributed.initialize`` + the CPU-collectives knob.

    The pinned toolchain's CPU backend refuses multi-process computations
    unless a cross-process collective implementation is selected; gloo ships
    in jaxlib, so forced-host-device simulation works out of the box.  Must
    run before the jax backend initializes.
    """
    global _initialized
    import jax
    if os.environ.get("JAX_PLATFORMS", "cpu").startswith("cpu") or \
            "xla_force_host_platform_device_count" in \
            os.environ.get("XLA_FLAGS", ""):
        try:
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except Exception:
            pass    # older/newer jaxlib without the knob: TPU/GPU don't need it
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    _initialized = True


def ensure_initialized() -> bool:
    """Autodetect the coordination env and initialize once; no-op (False)
    outside a multi-process launch, True when running distributed."""
    if is_initialized():
        return True
    coord, nproc, pid = env_info()
    if coord is None or not nproc or nproc < 2:
        return False
    if pid is None:
        raise RuntimeError(
            f"{ENV_NUM_PROCESSES[0]}={nproc} but no process id; set "
            f"{ENV_PROCESS_ID[0]} (the launcher does this per child)")
    initialize(coord, nproc, pid)
    return True


def process_count() -> int:
    import jax
    return jax.process_count()


def process_index() -> int:
    import jax
    return jax.process_index()


def is_primary() -> bool:
    """True on the process that should print/save gathered results."""
    return process_index() == 0


#: the canonical Fig-4 device-count ladder
DEVICE_LADDER = (1, 2, 4, 8, 16, 32, 64)


def covering_device_counts(ladder=DEVICE_LADDER) -> tuple[int, ...]:
    """The ladder values usable as a distributed mesh size here: every
    process must own >= 1 shard (so counts below the process count drop
    out) and the count can't exceed the global device total.  When no
    ladder value qualifies (e.g. 3 hosts x 1 device), the full global mesh
    always covers, so it is the fallback."""
    import jax
    counts = tuple(k for k in ladder
                   if jax.process_count() <= k <= jax.device_count())
    return counts or (jax.device_count(),)


# ---------------------------------------------------------------------------
# gathering
# ---------------------------------------------------------------------------

def gather_result(res):
    """Merge every process's copy of ``res`` into one global BenchResult.

    Every process runs the identical SPMD measurement loop, so the point
    lists line up index-for-index; only the timings differ (per-process
    clock skew around each global serialization point).  The merged point
    takes the timing triple of the process with the largest mean — aggregate
    bandwidth is global bytes over the straggler's wall time — and gbps /
    gflops are recomputed from it.  Per-process means are kept in
    ``meta["per_process_mean_s"]`` (process-indexed rows, point-indexed
    columns) and the machine meta grows ``process_count`` plus per-host
    ``local_device_counts``.  Identity (and the input object) on a
    single-process run.
    """
    import jax
    if jax.process_count() == 1:
        return res
    import dataclasses

    import numpy as np
    from jax.experimental import multihost_utils

    # one allgather for all points: rows tagged with the sender's process
    # index so merge order never depends on allgather's device ordering
    local = np.array([[float(jax.process_index()),
                       float(jax.local_device_count())]
                      + [s for p in res.points
                         for s in (p.mean_s, p.std_s, p.min_s)]])
    rows = multihost_utils.process_allgather(local).reshape(
        jax.process_count(), -1)
    rows = rows[np.argsort(rows[:, 0])]          # process-index order
    stats = rows[:, 2:].reshape(jax.process_count(), len(res.points), 3)

    merged = []
    for i, p in enumerate(res.points):
        slowest = int(np.argmax(stats[:, i, 0]))
        mean_s, std_s, min_s = (float(v) for v in stats[slowest, i])
        merged.append(dataclasses.replace(
            p, mean_s=mean_s, std_s=std_s, min_s=min_s,
            gbps=p.bytes_per_call / mean_s / 1e9 if mean_s else 0.0,
            gflops=p.flops_per_call / mean_s / 1e9 if mean_s else 0.0))
    res.points = merged
    res.meta["per_process_mean_s"] = stats[:, :, 0].tolist()
    res.machine["process_count"] = jax.process_count()
    res.machine["local_device_counts"] = [int(r[1]) for r in rows]
    _gather_traces()
    return res


def _gather_traces() -> None:
    """Allgather every process's span-trace events and install the merged
    stream (pids re-stamped to mesh process indices) on ALL processes —
    process 0 then writes ONE trace showing probe and generator shards,
    stragglers included.  A no-op while tracing is disabled (nothing is
    gathered, zero cost).  Events are self-describing variable-length JSON,
    so each allgathered row carries its own 8-byte length header and pads
    to the global max — row *order* from the collective is irrelevant."""
    import jax
    tr = trace.get_tracer()
    if not tr.enabled or jax.process_count() == 1:
        return
    import json

    import numpy as np
    from jax.experimental import multihost_utils

    events = tr.events()
    for e in events:        # stamp mesh identity before the OS pid is lost
        e["pid"] = jax.process_index()
    payload = np.frombuffer(json.dumps(events).encode(), dtype=np.uint8)
    sizes = multihost_utils.process_allgather(
        np.array([payload.size], dtype=np.int64))
    cap = int(np.max(sizes))
    row = np.zeros(cap + 8, dtype=np.uint8)
    row[:8] = np.frombuffer(np.array([payload.size], "<i8").tobytes(),
                            np.uint8)
    row[8:8 + payload.size] = payload
    gathered = multihost_utils.process_allgather(row)
    per_proc: dict[int, list[dict]] = {}
    for r in np.asarray(gathered).reshape(-1, cap + 8):
        n = int(np.frombuffer(bytes(r[:8]), "<i8")[0])
        evs = json.loads(bytes(r[8:8 + n]).decode())
        if evs:
            per_proc[evs[0]["pid"]] = evs
    streams = [per_proc.get(i, []) for i in range(jax.process_count())]
    tr.replace_events(trace.merge_process_traces(streams))


# ---------------------------------------------------------------------------
# local launcher (single-machine multi-process simulation)
# ---------------------------------------------------------------------------

def pick_free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _pump(proc, prefix, sink):
    for line in proc.stdout:
        sink.write(f"{prefix}{line}")
        sink.flush()


def launch_local(cmd: list[str], processes: int,
                 devices_per_process: int = 1,
                 coordinator_port: int | None = None,
                 env: dict | None = None, timeout: float | None = None,
                 stream_to=None) -> int:
    """Spawn ``cmd`` as ``processes`` coordinated local processes.

    Each child gets the REPRO_* coordination triple plus
    ``--xla_force_host_platform_device_count=devices_per_process`` appended
    to ``XLA_FLAGS`` (appended last, so it wins over any count the command
    sets for its single-process path) — the global mesh the children see has
    ``processes * devices_per_process`` devices.  Child stdout/stderr are
    streamed line-by-line with a ``[pK]`` prefix.  Returns the max child
    return code; on the first failure — *whichever* child fails first — the
    stragglers are killed rather than left waiting at a coordination
    barrier, and a ``timeout`` (seconds, for the whole launch) likewise
    kills everything and reports nonzero instead of raising.
    """
    if processes < 1:
        raise ValueError(f"processes must be >= 1: {processes}")
    if devices_per_process < 1:
        raise ValueError(
            f"devices_per_process must be >= 1: {devices_per_process}")
    port = coordinator_port or pick_free_port()
    base = dict(env if env is not None else os.environ)
    xla_flags = (base.get("XLA_FLAGS", "") + " --xla_force_host_platform_"
                 f"device_count={devices_per_process}").strip()
    sink = stream_to or sys.stderr
    procs, pumps = [], []
    deadline = None if timeout is None else time.monotonic() + timeout
    rc = 0
    tr = trace.get_tracer()
    launch_span = tr.span("launch.local", cat="launch", processes=processes,
                          devices_per_process=devices_per_process)
    launch_span.__enter__()
    try:
        # spawn INSIDE the cleanup scope: a Popen failure partway through
        # (EMFILE, OOM) must not leak already-started children blocked at
        # the coordination barrier
        for i in range(processes):
            child_env = dict(base,
                             XLA_FLAGS=xla_flags,
                             REPRO_COORDINATOR=f"127.0.0.1:{port}",
                             REPRO_NUM_PROCESSES=str(processes),
                             REPRO_PROCESS_ID=str(i))
            p = subprocess.Popen(cmd, env=child_env, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            procs.append(p)
            t = threading.Thread(target=_pump, args=(p, f"[p{i}] ", sink),
                                 daemon=True)
            t.start()
            pumps.append(t)
        # poll ALL children (a sequential wait would hang on a live earlier
        # child blocked at a collective barrier while a later one lies dead)
        pending = set(procs)
        while pending:
            for p in list(pending):
                code = p.poll()
                if code is not None:
                    pending.discard(p)
                    if code:    # negative = killed by signal, still a failure
                        rc = max(rc, code if code > 0 else 1)
            if rc:          # a dead peer wedges the others at a barrier
                break
            if deadline is not None and time.monotonic() > deadline:
                sink.write(f"# launch_local: timeout after {timeout}s, "
                           f"killing {len(pending)} process(es)\n")
                tr.event("launch.timeout", cat="launch", timeout_s=timeout,
                         pending=len(pending))
                rc = 1
                break
            if pending:
                time.sleep(0.05)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
                rc = max(rc, 1)
                metrics.REGISTRY.inc("straggler_kills")
                tr.event("launch.straggler_kill", cat="launch",
                         process=procs.index(p), rc=rc)
        launch_span.__exit__(None, None, None)
    for t in pumps:
        t.join(timeout=5)
    return rc
