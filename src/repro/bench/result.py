"""Versioned result schema: BenchPoint / BenchResult.

Supersedes ``core.sweep.SweepPoint``: every point carries its backend, the
addressing knobs it was measured under, and explicit bytes/flops accounting
(from the shared mix registry), so results from different backends/machines
are directly comparable.  The envelope carries ``schema_version``, the spec
that produced it, and machine metadata — a result file is a reproducible
record, not just numbers.

schema_version history: 1 = original point schema; 2 = points carry
``devices`` (the multi-device knob); 3 = points carry ``nbytes_requested``
(the pre-rounding spec size, so ``by_size`` resolves requested sizes), the
machine meta records process identity (``process_count`` /
``process_index`` / ``local_device_count`` — the ``distributed`` backend),
and unbounded ``summarize`` bands serialize as ``null`` instead of the
non-JSON ``Infinity``; 4 = points carry the instruction-stream knobs
(``unroll`` / ``interleave``) and an optional ``istream`` dict — the
per-point compiled-IR instruction profile + bandwidth-vs-issue-bound label
attached by ``repro.istream``; 5 = points carry the loaded-latency axes
(``load`` generator count, per-step ``latency_ns``, aggregate generator
``gen_gbps`` — the Mess-style bandwidth–latency curve coordinates; None /
0 on non-chase points); 6 = points retain their raw per-rep timing samples
(``rep_times_s``, bounded to the last ``REP_SAMPLE_LIMIT`` reps — enough
for the run ledger's noise-aware regression test to compute per-cell CIs
instead of trusting the mean triple) and the envelope meta carries the
``obs`` observability snapshot (``repro.obs``: per-run counter deltas —
cache hits/misses, buffer lifecycle, peak working-set bytes — plus the
Runner's cumulative cache counters, which used to die with the Runner
object).  Older files load unchanged with the defaults.
"""
from __future__ import annotations

import json
import math
import platform
from dataclasses import asdict, dataclass, field
from pathlib import Path

SCHEMA_VERSION = 6

#: per-point raw-sample retention (schema v6): the last this-many rep
#: timings survive into the result — bounded so a 10k-rep soak doesn't
#: bloat every record, plenty for a two-sample noise test
REP_SAMPLE_LIMIT = 64


def level_band(level_size: int | None,
               prev_size: float) -> tuple[float, float]:
    """Working-set band that cleanly sits inside one hierarchy level:
    (2x previous level, 0.5x this level); an unbounded level (DRAM/HBM,
    ``level_size=None``) opens to infinity.  The paper's §6 banding
    discipline, defined ONCE — ``summarize`` and ``core.analysis`` (which
    re-exports this) both read it."""
    lo = 2.0 * prev_size
    hi = 0.5 * level_size if level_size else float("inf")
    return lo, hi


@dataclass(frozen=True)
class BenchPoint:
    nbytes: int                 # real working-set bytes
    mix: str
    dtype: str
    backend: str
    passes: int
    streams: int
    block_rows: int | None
    reps: int
    bytes_per_call: float       # registry accounting x passes
    flops_per_call: float
    mean_s: float
    std_s: float
    min_s: float
    gbps: float
    gflops: float
    devices: int = 1            # schema v2; v1 files load with the default
    nbytes_requested: int | None = None     # schema v3: the spec size before
    #   buffers.working_set_shape rounding (None on pre-v3 files)
    unroll: int = 1             # schema v4: instruction-stream knobs
    interleave: int = 1
    istream: dict | None = None     # schema v4: repro.istream attaches the
    #   compiled-IR profile + bound classification here (None = not analyzed)
    load: int = 0               # schema v5: co-scheduled bandwidth generators
    latency_ns: float | None = None     # schema v5: ns per dependent chase
    #   step (chase mixes only; the loaded-latency curve's y axis)
    gen_gbps: float | None = None       # schema v5: aggregate generator GB/s
    #   (chase mixes: 0.0 at load=0; the loaded-latency curve's x axis)
    rep_times_s: tuple[float, ...] | None = None    # schema v6: raw per-rep
    #   timings, last REP_SAMPLE_LIMIT reps (None on pre-v6 files) — the
    #   ledger's regression gate derives per-cell noise sigmas from these

    def __post_init__(self):
        # canonicalize to a tuple so the frozen point stays hashable (JSON
        # round-trips hand from_dict a list); baseline_relative groups
        # points in dicts
        if self.rep_times_s is not None and not isinstance(self.rep_times_s,
                                                           tuple):
            object.__setattr__(self, "rep_times_s", tuple(self.rep_times_s))


@dataclass
class BenchResult:
    points: list[BenchPoint] = field(default_factory=list)
    spec: dict = field(default_factory=dict)       # BenchSpec.to_dict()
    machine: dict = field(default_factory=dict)    # machine_meta()
    meta: dict = field(default_factory=dict)       # run-level extras (dtype..)
    schema_version: int = SCHEMA_VERSION

    # -- queries ------------------------------------------------------------
    def by_mix(self, mix: str) -> list[BenchPoint]:
        return [p for p in self.points if p.mix == mix]

    def by_size(self, nbytes: int) -> list[BenchPoint]:
        """Points at a working-set size — matching either the *real*
        (rounded) byte count or the size as requested on the spec.
        ``buffers.working_set_shape`` rounds requests to whole (8, 128)
        tiles, so ``by_size(spec.sizes[i])`` historically returned ``[]``
        for any size the rounding moved; points now carry both (schema v3)
        and either resolves here."""
        return [p for p in self.points
                if p.nbytes == nbytes or p.nbytes_requested == nbytes]

    def baseline_relative(self, group_key=None, is_baseline=None
                          ) -> list[tuple[BenchPoint, float]]:
        """Each point's throughput relative to its group's baseline point.

        The baseline is the *first* point in each group satisfying
        ``is_baseline`` (default: the first point seen).  Anchoring uses an
        explicit presence check — a measured 0.0 GB/s baseline stays the
        baseline instead of silently re-anchoring on the next point (the
        ``base = base or gbps`` truthiness bug this replaces).
        """
        group_key = group_key or (lambda p: p.nbytes)
        bases: dict = {}
        for p in self.points:
            g = group_key(p)
            if g not in bases and (is_baseline is None or is_baseline(p)):
                bases[g] = p.gbps
        out = []
        for p in self.points:
            base = bases.get(group_key(p))
            rel = p.gbps / base if base else float("nan")
            out.append((p, rel))
        return out

    def summarize(self, levels=None, min_band_bytes: int = 4 * 2**10,
                  key=None) -> dict:
        """Per-level bandwidth attribution folded into the result — the
        paper's §6 'cumulative mean per hierarchy level', as a view on the
        points, so figure scripts stop re-deriving L1/L2/DRAM tables.

        ``levels`` is an ordered sequence (innermost first) of memory levels:
        either ``(name, size_bytes)`` pairs or objects with ``.name`` /
        ``.size_bytes`` attributes (e.g. ``core.machine_model.MemLevel``);
        ``size_bytes=None`` means unbounded (DRAM/HBM).  ``None`` summarizes
        everything into a single ``"all"`` level.  Each level's band is
        (2x previous level size, 0.5x this level size) so the mean sits
        cleanly inside one level; the innermost band opens at
        ``min_band_bytes``.

        Returns ``{level: {mix: {"gbps", "rel", "n", "band"}}}`` where
        ``rel`` is the mix's throughput relative to the best mix at that
        level (the paper's FADD/NOP/LOAD penalty ratios) and ``n`` the point
        count inside the band.  Levels with no points are omitted.  An
        unbounded band's upper edge is ``None`` (NOT ``float("inf")``): a
        summary stashed into ``meta`` must survive ``to_json``, and JSON has
        no ``Infinity`` — consumers treat a ``None`` edge as open.

        ``key`` overrides the per-point grouping column (default: the mix
        name) — e.g. ``lambda p: f"{p.mix}/u{p.unroll}x{p.interleave}"``
        groups a knob sweep by the instruction-stream axes.  A plain string
        names a BenchPoint field to group by (``summarize(key="load")``
        groups a loaded-latency sweep by generator count); field values are
        rendered with ``str()`` so the summary survives a ``meta`` JSON
        round-trip (JSON object keys are strings).  Prefer string keys if
        the summary is stashed into ``meta``.
        """
        if levels is None:
            levels = (("all", None),)
        if isinstance(key, str):
            col = key
            key = lambda p: str(getattr(p, col))  # noqa: E731
        key = key or (lambda p: p.mix)
        out: dict[str, dict] = {}
        prev = min_band_bytes / 2.0
        for lvl in levels:
            name, size = (lvl if isinstance(lvl, (tuple, list))
                          else (lvl.name, lvl.size_bytes))
            lo, hi = level_band(size, prev)
            mixes: dict[str, dict] = {}
            for p in self.points:
                if lo <= p.nbytes <= hi:
                    cell = mixes.setdefault(key(p), {"gbps": 0.0, "n": 0})
                    cell["gbps"] += p.gbps
                    cell["n"] += 1
            if mixes:
                best = max(c["gbps"] / c["n"] for c in mixes.values())
                for c in mixes.values():
                    c["gbps"] /= c["n"]
                    c["rel"] = c["gbps"] / best if best else float("nan")
                    c["band"] = (lo, None if math.isinf(hi) else hi)
                out[name] = mixes
            if size:
                prev = size
        return out

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        # meta is the free-form envelope (stashed summaries, skip maps, …):
        # sanitize it so the emitted text is real JSON — Python's dump of
        # inf/nan ("Infinity"/"NaN") is rejected by spec-compliant parsers.
        # summarize() already emits None band edges; this catches everything
        # else (e.g. a NaN ``rel`` from an all-zero level).
        return {"schema_version": self.schema_version,
                "spec": self.spec, "machine": self.machine,
                "meta": _json_finite(self.meta),
                "points": [asdict(p) for p in self.points]}

    def to_json(self, path: str | Path | None = None) -> str:
        s = json.dumps(self.to_dict(), indent=2)
        if path is not None:
            Path(path).write_text(s)
        return s

    @classmethod
    def from_dict(cls, d: dict) -> "BenchResult":
        ver = d.get("schema_version", 0)
        if ver > SCHEMA_VERSION:
            raise ValueError(
                f"result schema_version {ver} newer than supported "
                f"{SCHEMA_VERSION}")
        return cls(points=[BenchPoint(**p) for p in d.get("points", [])],
                   spec=d.get("spec", {}), machine=d.get("machine", {}),
                   meta=d.get("meta", {}),
                   schema_version=ver or SCHEMA_VERSION)

    @classmethod
    def from_json(cls, path: str | Path) -> "BenchResult":
        return cls.from_dict(json.loads(Path(path).read_text()))


def _json_finite(obj):
    """Deep-copy ``obj`` with non-finite floats replaced by None (the JSON
    serialization of an unbounded/undefined value); containers are rebuilt
    (tuples as lists, matching what a JSON round-trip produces anyway)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _json_finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_finite(v) for v in obj]
    return obj


def machine_meta() -> dict:
    """Best-effort machine identity stamped into every result.  Process
    identity (schema v3) is 1-process/index-0 outside a ``jax.distributed``
    run; ``bench.distributed.gather_result`` extends the merged result with
    the per-host ``local_device_counts``."""
    import jax
    dev = jax.devices()[0]
    return {"hostname": platform.node(),
            "arch": platform.machine(),
            "system": platform.system(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device_platform": dev.platform,
            "device_kind": getattr(dev, "device_kind", str(dev)),
            "device_count": jax.device_count(),
            "process_count": jax.process_count(),
            "process_index": jax.process_index(),
            "local_device_count": jax.local_device_count()}
