"""The instruction-mix registry — C2 of the paper, declared exactly once.

Arm-membench's central idea is that the *same* data stream measured under
different instruction mixes (LOAD-only / LOAD+FADD / LOAD+NOP) attributes the
bottleneck.  Every mix the repo can run — through the XLA oracles *or* the
Pallas TPU embodiment — is declared here, with its own bytes/flops accounting,
so the two backends can never disagree about what a measurement means.

    mix            ops/element     Armv8 analogue
    ``load_only``  0               pure LD1D loop (Pallas-only: XLA DCE's a
                                   dead load, the Pallas pipeline DMAs the
                                   block into VMEM regardless)
    ``load_sum``   1 add           the FADD accumulation loop
    ``copy``       1 store         STREAM-copy (write path exercised)
    ``triad``      2 flops         STREAM-triad a = b + s*c (2 reads, 1 write)
    ``fma_k``      2k flops        NOP-substitution ladder: k-deep dependent
                                   FMA chain; the knee is the measured ridge
    ``mxu``        2*128 flops     one 128x128 matmul per tile (MXU saturation)

Consumers: ``repro.bench.backends`` (kernel dispatch), ``repro.bench.runner``
(work accounting), ``repro.core.instruction_mix`` (legacy ``mixes()`` view),
``repro.kernels.membench.ops.work_per_call`` (legacy accounting view).
"""
from __future__ import annotations

from dataclasses import dataclass

FMA_DEPTHS = (1, 2, 4, 8, 16, 32, 64)

# execution aliases: the sharded backend runs the xla oracles per shard, so a
# mix runnable on xla is runnable sharded (same kernels, same accounting)
_BACKEND_ALIASES = {"sharded": "xla"}


@dataclass(frozen=True)
class MixDef:
    """One instruction mix: name + per-element work accounting + backends."""
    name: str
    flops_per_elem: float          # arithmetic per element per pass
    reads_per_elem: float = 1.0
    writes_per_elem: float = 0.0
    backends: tuple[str, ...] = ("xla", "pallas")
    fma_depth: int = 0             # chain depth for the fma family
    description: str = ""

    def bytes_per_pass(self, nbytes: int) -> float:
        return (self.reads_per_elem + self.writes_per_elem) * nbytes

    def flops_per_pass(self, n_elems: int) -> float:
        return self.flops_per_elem * n_elems

    def supports(self, backend: str) -> bool:
        return _BACKEND_ALIASES.get(backend, backend) in self.backends


def _build_registry() -> dict[str, MixDef]:
    out = {
        "load_only": MixDef(
            "load_only", 0.0, backends=("pallas",),
            description="pure data movement; one lane feeds the accumulator"),
        "load_sum": MixDef(
            "load_sum", 1.0,
            description="load + accumulate (the FADD loop)"),
        "copy": MixDef(
            "copy", 0.0, reads_per_elem=1.0, writes_per_elem=1.0,
            description="STREAM copy: read stream + write stream"),
        "triad": MixDef(
            "triad", 2.0, reads_per_elem=2.0, writes_per_elem=1.0,
            description="STREAM triad a = b + s*c"),
        "mxu": MixDef(
            "mxu", 2.0 * 128.0,
            description="one (rows,128)@(128,128) matmul per tile"),
    }
    for k in FMA_DEPTHS:
        out[f"fma_{k}"] = MixDef(
            f"fma_{k}", 2.0 * k, fma_depth=k,
            description=f"{k}-deep dependent FMA chain per element")
    return out


_REGISTRY: dict[str, MixDef] = _build_registry()


def registry() -> dict[str, MixDef]:
    """name -> MixDef for every known mix (shared, do not mutate)."""
    return dict(_REGISTRY)


def get_mix(name: str) -> MixDef:
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.startswith("fma_"):
        # the fma family is open-ended: any positive chain depth is a valid
        # mix (registry() lists only the canonical ladder)
        try:
            k = int(name.split("_", 1)[1])
        except ValueError:
            k = 0
        if k >= 1:
            return MixDef(name, 2.0 * k, fma_depth=k,
                          description=f"{k}-deep dependent FMA chain per element")
    raise KeyError(f"unknown mix {name!r}; known: {sorted(_REGISTRY)}")


def mix_names(backend: str | None = None) -> list[str]:
    """All mix names, optionally only those a given backend supports."""
    return [m.name for m in _REGISTRY.values()
            if backend is None or m.supports(backend)]
