"""Pluggable benchmark backends: the XLA oracles, the Pallas embodiment, and
the sharded / distributed multi-device backends (the paper's Figure-4
core-scaling study, single-process and multi-process respectively).

A Backend turns (BenchSpec, mix, working set, passes) into a zero-arg callable
whose return value is the serialization point for timing.  Work accounting is
NOT a backend concern — the Runner reads it from the shared mix registry, so
all backends report identical bytes/flops for the same spec by construction.

The built-in backends split ``build`` into two halves so the Runner can cache
the expensive one:

    make_case(spec, mix, shape, dtype, passes)   the compiled callable —
        a pure function of the knobs and the buffer *shape*, never closing
        over a buffer.  The Runner caches these by key (see ``case_key``),
        so knob sweeps (``run_many``) and ``compare`` stop re-tracing
        identical kernels, and a cached case can never retain a working set.
    bind_case(case, spec, mix, x)                per-buffer binding —
        closes over the actual working set (plus any companion buffers,
        e.g. triad's second read stream) and is rebuilt per size, then
        dropped with the buffer.

Third-party backends only need ``build`` (the original protocol); the Runner
falls back to it, uncached, when ``make_case`` is absent.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.bench.mixes import MixDef, get_mix, interleavable
from repro.bench.spec import BenchSpec, BenchSpecError, knob_names
from repro.obs import trace


#: BenchSpec fields that can NEVER change what make_case compiles — either
#: they are explicit slots of the cache key already (mixes/sizes/dtype/
#: backend/passes resolve to the per-case key columns) or they only shape
#: the measurement around the compiled case (repetition discipline, buffer
#: fill value, labels).  Everything else — including any FUTURE knob — is
#: part of the key by default: forgetting to classify a new field makes the
#: cache miss, never alias.
_NON_CASE_FIELDS = frozenset({
    "mixes", "sizes", "dtype", "backend", "passes",     # explicit key slots
    "reps", "warmup", "value", "target_bytes", "tags",  # measurement-only
})


def case_knobs(spec: BenchSpec) -> tuple:
    """(name, value) pairs of every spec field that can affect compilation,
    derived from the dataclass fields (not an explicit list) so new knobs
    are cache-safe by construction.  Shared by ``case_key`` and the istream
    profile cache."""
    import dataclasses
    return tuple((f.name, getattr(spec, f.name))
                 for f in dataclasses.fields(spec)
                 if f.name not in _NON_CASE_FIELDS)


def _gate(backend_name: str, rule: str) -> str:
    """Suffix naming the backend gate that rejected a knob combination, plus
    the valid knob names — so the error decodes without opening spec.py."""
    return (f" [gate: {rule}, raised by {backend_name}.validate; valid spec "
            f"knobs: {', '.join(knob_names())}]")


@runtime_checkable
class Backend(Protocol):
    """One way of executing a mix on a device."""
    name: str

    def supports(self, mix: MixDef) -> bool:
        """Can this backend run the mix at all (knobs aside)?"""
        ...

    def validate(self, spec: BenchSpec) -> None:
        """Raise BenchSpecError for knob combinations this backend can't run."""
        ...

    def build(self, spec: BenchSpec, mix: MixDef, x, passes: int
              ) -> Callable[[], object]:
        """Zero-arg callable running `passes` passes of `mix` over `x`; the
        returned jax array is the block_until_ready serialization point."""
        ...


class _CaseBackend:
    """Shared make_case/bind_case machinery for the built-in backends."""
    multi_device = False     # True: accepts BenchSpec(devices > 1)

    def case_key(self, spec: BenchSpec, mix: MixDef, shape, dtype,
                 passes: int) -> tuple:
        """Everything ``make_case`` depends on — the Runner's cache key.
        The knob columns derive from the FULL spec field set minus the
        measurement-only fields (``case_knobs``), so a future knob that
        changes compilation can never alias a stale cached case."""
        return (self.name, mix.name, tuple(shape), str(dtype), passes,
                case_knobs(spec))

    def make_case(self, spec: BenchSpec, mix: MixDef, shape, dtype,
                  passes: int) -> Callable:
        raise NotImplementedError

    def prepare_buffer(self, spec: BenchSpec, x):
        """Per-size buffer placement hook, called once before binding that
        size's cases (e.g. the sharded backend spreads x over its mesh here
        so per-mix bindings share one placed copy)."""
        return x

    def abstract_args(self, spec: BenchSpec, mix: MixDef, shape, dtype
                      ) -> tuple:
        """ShapeDtypeStructs matching ``make_case``'s positional buffers —
        what ``jax.jit(case).lower(...)`` needs (the istream extractor
        lowers cached cases without materializing working sets)."""
        import jax
        sds = jax.ShapeDtypeStruct(tuple(shape), dtype)
        if mix.chase:
            perm = jax.ShapeDtypeStruct(tuple(shape), jnp.int32)
            return (perm, sds) if spec.load else (perm,)
        return (sds,) * _mix_arity(mix)

    def bind_case(self, case: Callable, spec: BenchSpec, mix: MixDef, x
                  ) -> Callable[[], object]:
        return lambda: case(x)

    def build(self, spec, mix, x, passes):
        case = self.make_case(spec, mix, x.shape, x.dtype, passes)
        return self.bind_case(case, spec, mix, self.prepare_buffer(spec, x))


def _validate_oracle_knobs(spec: BenchSpec, backend_name: str) -> None:
    """Knob rules of the core.instruction_mix oracles (shared by the xla
    backend and the sharded backend, which runs the same kernels per shard)."""
    for m in spec.mixes:
        mix = get_mix(m)
        if "xla" not in mix.backends:
            raise BenchSpecError(f"mix {m!r} not supported on {backend_name}"
                                 + _gate(backend_name, "mix support"))
        if spec.streams > 1 and m != "load_sum":
            raise BenchSpecError(
                f"{backend_name} backend expresses streams>1 only for "
                f"load_sum (the strided-walk oracle); got mix {m!r}"
                + _gate(backend_name, "streams>1 needs the strided oracle"))
        if spec.block_rows is not None and m != "load_sum":
            raise BenchSpecError(
                f"{backend_name} backend expresses block_rows only for "
                f"load_sum (the blocked-walk oracle); got mix {m!r}"
                + _gate(backend_name, "block_rows needs the blocked oracle"))
        if spec.interleave > 1 and not interleavable(mix):
            raise BenchSpecError(
                f"mix {m!r} has no interleaved variant on {backend_name} "
                f"(interleave>1 needs independent per-chunk chains — "
                f"load_sum, copy, or the rw_RtoW family)"
                + _gate(backend_name, "interleave>1 needs an interleavable "
                                      "mix"))
    if spec.streams > 1 and spec.block_rows is not None:
        raise BenchSpecError(f"{backend_name} backend: streams and "
                             "block_rows are mutually exclusive knobs"
                             + _gate(backend_name,
                                     "streams xor block_rows"))
    if spec.interleave > 1 and (spec.streams > 1
                                or spec.block_rows is not None):
        raise BenchSpecError(
            f"{backend_name} backend: interleave>1 does not compose with "
            f"streams>1 or block_rows (the interleaved oracles walk the "
            f"whole buffer in row chunks)"
            + _gate(backend_name, "interleave xor streams/block_rows"))


def _mix_arity(mix: MixDef, load: int = 0) -> int:
    """Positional buffer count of a mix's oracle case (reads then writes).
    A chase probe takes its permutation buffer, plus the generator working
    set when ``load`` generators are composed in."""
    if mix.chase:
        return 2 if load else 1
    if mix.name == "triad":
        return 3
    if mix.rw is not None:
        return mix.rw[0] + mix.rw[1]
    return 1


def _mix_operands(mix: MixDef, x, place=lambda a: a, load: int = 0,
                  parts: int = 1) -> tuple:
    """Every buffer a mix's oracle case consumes, in positional order, built
    OUTSIDE the timed call.  ``x`` passes through as-is (the Runner already
    placed it via prepare_buffer); companion streams — triad's (a, c), the rw
    family's extra read and write streams, the chase probe's permutation
    buffer (``parts`` local cycles: one per mesh shard) — go through
    ``place`` (identity on xla, a mesh device_put on sharded)."""
    if mix.chase:
        from repro.core.instruction_mix import chase_perm
        perm = place(jnp.asarray(chase_perm(x.shape, parts)))
        return (perm, x) if load else (perm,)
    if mix.name == "triad":
        return (place(jnp.zeros_like(x)), x, place(x * 0.5))
    if mix.rw is not None:
        from repro.core.instruction_mix import rw_streams
        reads, writes = mix.rw
        # the W write-seed slots only supply shape/dtype — k_rw overwrites
        # every output before reading it — so alias x rather than allocating
        # W zero buffers (peak footprint stays one working set + companions)
        return ((x,)
                + tuple(place(s) for s in rw_streams(x, reads)[1:])
                + (x,) * writes)
    return (x,)


def _oracle_case(spec: BenchSpec, mix: MixDef, rows: int, passes: int,
                 backend_name: str) -> Callable:
    """The per-shape oracle kernel for a mix (pure function of its inputs;
    triad takes (a, b, c), rw_RtoW takes its R+W stream buffers, everything
    else takes x)."""
    from repro.core import instruction_mix as im
    unroll, interleave = spec.unroll, spec.interleave
    if passes % unroll:
        # the Runner rounds auto-picked passes up; a direct build() with
        # explicit passes surfaces here instead of a trace-time ValueError
        raise BenchSpecError(
            f"passes={passes} is not a multiple of unroll={unroll}"
            + _gate(backend_name, "passes % unroll == 0"))
    if interleave > 1 and rows % interleave:
        raise BenchSpecError(
            f"interleave {interleave} does not divide {rows} rows"
            + ("" if backend_name == "xla" else
               f" (the per-device shard on {backend_name})")
            + _gate(backend_name, "interleave | rows"))
    if mix.name == "load_sum" and spec.streams > 1:
        streams = spec.streams
        return lambda x: im.k_strided_sum(x, streams, passes, unroll)
    if mix.name == "load_sum" and spec.block_rows is not None:
        brows = spec.block_rows
        if rows % brows:
            raise BenchSpecError(
                f"block_rows {brows} does not divide {rows} rows"
                + ("" if backend_name == "xla" else
                   f" (the per-device shard on {backend_name})"))
        return lambda x: im.k_blocked_sum(x, brows, passes, unroll)
    if mix.chase:
        load = spec.load
        if load:
            # the single-device composite: probe + generators time-shared in
            # one timed computation (the mesh backends build their own
            # probe-on-shard-0 composite in make_case instead)
            return lambda perm, gen: im.k_chase_loaded(perm, gen, passes,
                                                       unroll, load=load)
        return lambda perm: im.k_chase(perm, passes, unroll)
    if mix.name == "triad":
        return lambda a, b, c: im.k_triad(a, b, c, passes, unroll)
    if mix.rw is not None:
        reads = mix.rw[0]
        if interleave > 1:
            return lambda *bufs: im.k_rw_istream(
                bufs[:reads], bufs[reads:], passes, unroll, interleave)
        return lambda *bufs: im.k_rw(bufs[:reads], bufs[reads:], passes,
                                     unroll)
    name = mix.name
    return lambda x: im.run_mix(name, x, passes, unroll=unroll,
                                interleave=interleave)


def _bind_oracle_case(case: Callable, mix: MixDef, x, load: int = 0
                      ) -> Callable[[], object]:
    """Close an oracle case over its buffers; companion streams are built
    here, outside the timed call (shared by xla and sharded)."""
    bufs = _mix_operands(mix, x, load=load)
    return lambda: case(*bufs)


class XLABackend(_CaseBackend):
    """The jnp oracles from core.instruction_mix (host-measurable)."""
    name = "xla"

    def supports(self, mix: MixDef) -> bool:
        return self.name in mix.backends

    def validate(self, spec: BenchSpec) -> None:
        _validate_oracle_knobs(spec, self.name)

    def make_case(self, spec, mix, shape, dtype, passes):
        trace.event("backend.dispatch", backend=self.name, mix=mix.name,
                    load=spec.load)
        return _oracle_case(spec, mix, shape[0], passes, self.name)

    def bind_case(self, case, spec, mix, x):
        return _bind_oracle_case(case, mix, x, load=spec.load)


class _MeshOracleBackend(_CaseBackend):
    """Shared machinery for backends that run the instruction-mix oracles
    per shard of a 1-D device mesh (``sharded`` on local devices,
    ``distributed`` on the global devices of a multi-process run).

    Subclasses choose the device pool (``_mesh_devices``) and how a host
    buffer becomes a mesh-placed array (``_place``); ``make_case`` — the
    shard_map wrapping of the *same* oracle kernels the xla backend runs —
    is identical for both, so bytes/flops accounting parity across xla /
    sharded / distributed holds by construction (the Runner reads accounting
    from the shared mix registry, never from the backend).
    """
    multi_device = True

    def __init__(self):
        self._meshes: dict[int, object] = {}

    def supports(self, mix: MixDef) -> bool:
        # mixes._BACKEND_ALIASES maps sharded/distributed -> xla (single
        # source of truth for which mixes the oracles implement)
        return mix.supports(self.name)

    def _mesh_devices(self) -> list:
        """The device pool the 1-D mesh draws from (first k are used)."""
        import jax
        return jax.devices()

    def _mesh(self, k: int):
        mesh = self._meshes.get(k)
        if mesh is None:
            import numpy as np
            from jax.sharding import Mesh
            devs = self._mesh_devices()
            if k > len(devs):
                raise BenchSpecError(
                    f"devices={k} exceeds the {len(devs)} visible device(s); "
                    "force host devices with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N")
            mesh = Mesh(np.array(devs[:k]).reshape(k), ("d",))
            self._meshes[k] = mesh
        return mesh

    def validate(self, spec: BenchSpec) -> None:
        _validate_oracle_knobs(spec, self.name)
        if spec.load and spec.devices != spec.load + 1:
            raise BenchSpecError(
                f"{self.name} backend places the latency probe on shard 0 "
                f"and each of the {spec.load} generator(s) on its own "
                f"sibling shard: need devices == load + 1 "
                f"({spec.load + 1}), got devices={spec.devices}"
                + _gate(self.name, "devices == load + 1"))
        self._mesh(spec.devices)        # device-count check

    def make_case(self, spec, mix, shape, dtype, passes):
        import jax
        from jax.sharding import PartitionSpec as P
        k = spec.devices
        rows, lanes = shape
        if rows % k:
            raise BenchSpecError(
                f"devices={k} does not divide the {rows}-row working set")
        mesh = self._mesh(k)
        # dispatch provenance: which backend, what mesh shape, and whether a
        # generator co-schedule is composed in (the loaded-latency split)
        trace.event("backend.dispatch", backend=self.name, mix=mix.name,
                    mesh_shape=[k], load=spec.load,
                    composite=bool(mix.chase and spec.load))
        n_args = _mix_arity(mix, spec.load)   # triad: (a,b,c); rw: R+W

        if mix.chase and spec.load:
            # the mesh composite: ONE timed computation in which shard 0
            # walks its pointer cycle (the probe) while every sibling shard
            # runs load_sum sweeps over its slice of the generator buffer
            # (the bandwidth generators) — real spatial co-scheduling, not
            # the single-device time-shared emulation
            from repro.bench.mixes import GEN_SWEEPS_PER_PASS
            from repro.core import instruction_mix as im
            if passes % spec.unroll:
                raise BenchSpecError(
                    f"passes={passes} is not a multiple of "
                    f"unroll={spec.unroll}"
                    + _gate(self.name, "passes % unroll == 0"))
            gen_passes = passes * GEN_SWEEPS_PER_PASS
            unroll = spec.unroll

            def body(perm_v, gen_v):     # each v: (1, rows // k, lanes)
                out = jax.lax.cond(
                    jax.lax.axis_index("d") == 0,
                    lambda: im.k_chase(perm_v[0], passes, unroll),
                    lambda: im.k_load_sum(gen_v[0], gen_passes))
                return out.reshape(1)
        else:
            shard = _oracle_case(spec, mix, rows // k, passes, self.name)

            def body(*vs):               # each v: (1, rows // k, lanes)
                return shard(*(v[0] for v in vs)).reshape(1)

        smap = jax.shard_map(body, mesh=mesh,
                             in_specs=(P("d", None, None),) * n_args,
                             out_specs=P("d"), check_vma=False)

        @jax.jit
        def fn(*xs):
            return smap(*(x.reshape(k, rows // k, lanes) for x in xs)).sum()

        return fn

    def _sharding(self, k: int):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self._mesh(k), P("d", None))

    def _place(self, a, sharding):
        import jax
        return jax.device_put(a, sharding)

    def prepare_buffer(self, spec, x):
        """One mesh placement per size — every mix's binding shares it."""
        return self._place(x, self._sharding(spec.devices))

    def bind_case(self, case, spec, mix, x):
        # companions live outside the timed call, placed like x (which
        # prepare_buffer already spread across the mesh)
        sharding = self._sharding(spec.devices)
        bufs = _mix_operands(mix, x,
                             place=lambda a: self._place(a, sharding),
                             load=spec.load, parts=spec.devices)
        return lambda: case(*bufs)


class ShardedBackend(_MeshOracleBackend):
    """The working set spread over the first k devices of a 1-D mesh.

    Reproduces the paper's Figure-4 core-count scaling study (aggregate
    bandwidth vs cores until the HBM2 interface saturates): each device runs
    the *same* instruction-mix oracle the xla backend runs, over its shard,
    via ``shard_map`` — so every mix that runs on ``xla`` runs sharded, with
    identical bytes/flops accounting by construction (the Runner reads both
    from the shared registry).  ``BenchSpec(devices=k)`` picks the mesh size;
    at ``devices=1`` this degenerates to the xla backend plus mesh overhead.
    """
    name = "sharded"


class DistributedBackend(_MeshOracleBackend):
    """The sharded oracle-per-shard machinery over the **global** devices of
    a multi-process run (``jax.distributed``) — the paper's Fig-4 scaling
    study taken past one host.

    The ``devices`` knob is unchanged: it counts *global* mesh devices, so a
    spec that ran ``sharded`` on one 8-device host runs ``distributed`` on
    two 4-device hosts byte-for-byte (same accounting, same per-shard
    kernels; ``tests/test_bench_distributed.py`` enforces the parity).  Two
    things differ from ``sharded``:

    * buffer placement: a host-built working set becomes a *global* array
      via ``jax.make_array_from_callback`` — each process materializes only
      its addressable shards on device (``device_put`` can't target
      non-addressable shards on the pinned toolchain).  Companions computed
      *from* the placed buffer (triad's ``x * 0.5``, the rw streams) are
      already global and pass through untouched.
    * process roles: every process runs the identical SPMD measurement loop
      (the trailing cross-shard ``.sum()`` in the compiled case is the
      global serialization point each rep); afterwards
      ``bench.distributed.gather_result`` merges the per-process timings
      into one BenchResult on all processes and process 0 saves it.

    Initialization (``bench.distributed.ensure_initialized``) must happen
    before the jax backend comes up — the CLI's ``run``/``launch`` and
    ``benchmarks/fig4_scaling.py --distributed`` do this for you.  In a
    single-process context this backend degenerates to ``sharded`` exactly.
    """
    name = "distributed"

    def _mesh_devices(self) -> list:
        """Global devices, round-robin across processes — ``devices=k``
        spreads the mesh as evenly as the process topology allows (k=2 on
        2x2 hosts is one device per host, not two on host 0), so a Fig-4
        sweep over intermediate counts exercises the interconnect instead
        of a single host's slice of it."""
        import jax
        devs = jax.devices()
        if jax.process_count() == 1:
            return devs
        by_proc: dict[int, list] = {}
        for d in devs:
            by_proc.setdefault(d.process_index, []).append(d)
        pools = [by_proc[p] for p in sorted(by_proc)]
        return [pool[i] for i in range(max(len(p) for p in pools))
                for pool in pools if i < len(pool)]

    def validate(self, spec: BenchSpec) -> None:
        super().validate(spec)
        import jax
        if jax.process_count() > 1:
            # SPMD needs every process inside the mesh: a process owning no
            # shard has no addressable data and can't even represent the
            # computation — fail with the fix, not an IndexError deep in
            # placement
            covered = {d.process_index
                       for d in self._mesh_devices()[:spec.devices]}
            missing = sorted(set(range(jax.process_count())) - covered)
            if missing:
                raise BenchSpecError(
                    f"devices={spec.devices} leaves process(es) {missing} "
                    f"with no mesh shard; use devices >= one per process "
                    f"or launch fewer processes")

    def _place(self, a, sharding):
        import jax
        if isinstance(a, jax.Array) and not a.is_fully_addressable:
            return a        # already a global array living on the mesh
        if jax.process_count() == 1:
            return jax.device_put(a, sharding)
        import numpy as np
        host = np.asarray(a)
        return jax.make_array_from_callback(host.shape, sharding,
                                            lambda idx: host[idx])


class PallasBackend(_CaseBackend):
    """The Pallas TPU kernels (kernels/membench) with explicit VMEM tiling.

    interpret=True validates kernel-body semantics on CPU; on real TPU set
    BenchSpec(interpret=False) for wall-clock-meaningful numbers.
    """
    name = "pallas"
    DEFAULT_BLOCK_ROWS = 128

    def supports(self, mix: MixDef) -> bool:
        return self.name in mix.backends

    def _resolve(self, spec: BenchSpec, rows: int) -> int:
        if spec.block_rows is not None:
            return spec.block_rows       # explicit knob: never adjusted
        # default tiling must divide the buffer: largest sublane multiple
        # <= 128 that does (rows is always a multiple of 8, so 8 divides)
        r = min(self.DEFAULT_BLOCK_ROWS, rows)
        while r > 8 and rows % r:
            r -= 8
        return r

    def validate(self, spec: BenchSpec) -> None:
        for m in spec.mixes:
            mix = get_mix(m)
            if not self.supports(mix):
                raise BenchSpecError(f"mix {m!r} not supported on pallas"
                                     + _gate(self.name, "mix support"))
            if spec.interleave > 1 and not interleavable(mix):
                raise BenchSpecError(
                    f"mix {m!r} has no interleaved variant on pallas "
                    f"(interleave>1 needs independent per-chunk chains — "
                    f"load_sum, copy, or the rw_RtoW family)"
                    + _gate(self.name, "interleave>1 needs an "
                                       "interleavable mix"))

    def make_case(self, spec, mix, shape, dtype, passes):
        from repro.kernels.membench import ops as mb_ops
        rows = self._resolve(spec, shape[0])
        if rows > shape[0] or shape[0] % rows:
            raise BenchSpecError(
                f"block_rows {rows} does not divide {shape[0]} rows")
        n_blocks = shape[0] // rows
        if n_blocks % spec.streams:
            raise BenchSpecError(
                f"streams {spec.streams} does not divide {n_blocks} blocks")
        if passes % spec.unroll:
            raise BenchSpecError(
                f"passes={passes} is not a multiple of unroll={spec.unroll}"
                + _gate(self.name, "passes % unroll == 0"))
        if spec.interleave > 1 and rows % spec.interleave:
            raise BenchSpecError(
                f"interleave {spec.interleave} does not divide the "
                f"{rows}-row VMEM tile"
                + _gate(self.name, "interleave | block_rows"))
        trace.event("backend.dispatch", backend=self.name, mix=mix.name,
                    block_rows=rows, interpret=spec.interpret,
                    load=spec.load)
        return mb_ops.make_timed_kernel(
            mix.name, depth=mix.fma_depth or 8, block_rows=rows,
            streams=spec.streams, interpret=spec.interpret, passes=passes,
            unroll=spec.unroll, interleave=spec.interleave, load=spec.load)

    def abstract_args(self, spec, mix, shape, dtype):
        import jax
        sds = jax.ShapeDtypeStruct(tuple(shape), dtype)
        if mix.chase:
            perm = jax.ShapeDtypeStruct(tuple(shape), jnp.int32)
            return (perm, sds) if spec.load else (perm,)
        if mix.name == "triad":
            return (sds, sds)           # fn(x, y)
        if mix.rw is not None:
            return (sds,) * mix.rw[0]   # fn(x, *extra_read_streams)
        return (sds,)

    def bind_case(self, case, spec, mix, x):
        if mix.chase:
            # one pointer cycle per VMEM tile: the grid walks the tiles, the
            # kernel chases the current tile's TILE-LOCAL cycle
            from repro.core.instruction_mix import chase_perm
            rows = self._resolve(spec, x.shape[0])
            perm = jnp.asarray(chase_perm(x.shape, x.shape[0] // rows))
            if spec.load:
                return lambda: case(perm, x)
            return lambda: case(perm)
        if mix.name == "triad":
            y = x * 0.5
            return lambda: case(x, y)
        if mix.rw is not None:
            # the Pallas embodiment allocates its W outputs via out_shape;
            # only the R read streams are bound (outside the timed call)
            from repro.core.instruction_mix import rw_streams
            bufs = rw_streams(x, mix.rw[0])
            return lambda: case(*bufs)
        return lambda: case(x)


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _BACKENDS[backend.name] = backend
    return backend


register_backend(XLABackend())
register_backend(ShardedBackend())
register_backend(DistributedBackend())
register_backend(PallasBackend())


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)
