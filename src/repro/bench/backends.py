"""Pluggable benchmark backends: the XLA oracles and the Pallas embodiment.

A Backend turns (BenchSpec, mix, working set, passes) into a zero-arg callable
whose return value is the serialization point for timing.  Work accounting is
NOT a backend concern — the Runner reads it from the shared mix registry, so
the two backends report identical bytes/flops for the same spec by
construction.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.bench.mixes import MixDef, get_mix
from repro.bench.spec import BenchSpec, BenchSpecError


@runtime_checkable
class Backend(Protocol):
    """One way of executing a mix on a device."""
    name: str

    def supports(self, mix: MixDef) -> bool:
        """Can this backend run the mix at all (knobs aside)?"""
        ...

    def validate(self, spec: BenchSpec) -> None:
        """Raise BenchSpecError for knob combinations this backend can't run."""
        ...

    def build(self, spec: BenchSpec, mix: MixDef, x, passes: int
              ) -> Callable[[], object]:
        """Zero-arg callable running `passes` passes of `mix` over `x`; the
        returned jax array is the block_until_ready serialization point."""
        ...


class XLABackend:
    """The jnp oracles from core.instruction_mix (host-measurable)."""
    name = "xla"

    def supports(self, mix: MixDef) -> bool:
        return self.name in mix.backends

    def validate(self, spec: BenchSpec) -> None:
        for m in spec.mixes:
            mix = get_mix(m)
            if not self.supports(mix):
                raise BenchSpecError(f"mix {m!r} not supported on xla")
            if spec.streams > 1 and m != "load_sum":
                raise BenchSpecError(
                    "xla backend expresses streams>1 only for load_sum "
                    f"(the strided-walk oracle); got mix {m!r}")
            if spec.block_rows is not None and m != "load_sum":
                raise BenchSpecError(
                    "xla backend expresses block_rows only for load_sum "
                    f"(the blocked-walk oracle); got mix {m!r}")
        if spec.streams > 1 and spec.block_rows is not None:
            raise BenchSpecError("xla backend: streams and block_rows are "
                                 "mutually exclusive knobs")

    def build(self, spec, mix, x, passes):
        from repro.core import instruction_mix as im
        if mix.name == "load_sum" and spec.streams > 1:
            streams = spec.streams
            return lambda: im.k_strided_sum(x, streams, passes)
        if mix.name == "load_sum" and spec.block_rows is not None:
            rows = spec.block_rows
            if x.shape[0] % rows:
                raise BenchSpecError(
                    f"block_rows {rows} does not divide {x.shape[0]} rows")
            return lambda: im.k_blocked_sum(x, rows, passes)
        if mix.name == "triad":
            b, c = x, x * 0.5
            a = jnp.zeros_like(x)
            return lambda: im.k_triad(a, b, c, passes)
        return lambda: im.run_mix(mix.name, x, passes)


class PallasBackend:
    """The Pallas TPU kernels (kernels/membench) with explicit VMEM tiling.

    interpret=True validates kernel-body semantics on CPU; on real TPU set
    BenchSpec(interpret=False) for wall-clock-meaningful numbers.
    """
    name = "pallas"
    DEFAULT_BLOCK_ROWS = 128

    def supports(self, mix: MixDef) -> bool:
        return self.name in mix.backends

    def _resolve(self, spec: BenchSpec, x) -> int:
        if spec.block_rows is not None:
            return spec.block_rows       # explicit knob: never adjusted
        return min(self.DEFAULT_BLOCK_ROWS, x.shape[0])

    def validate(self, spec: BenchSpec) -> None:
        for m in spec.mixes:
            if not self.supports(get_mix(m)):
                raise BenchSpecError(f"mix {m!r} not supported on pallas")

    def build(self, spec, mix, x, passes):
        from repro.kernels.membench import ops as mb_ops
        rows = self._resolve(spec, x)
        if rows > x.shape[0] or x.shape[0] % rows:
            raise BenchSpecError(
                f"block_rows {rows} does not divide {x.shape[0]} rows")
        n_blocks = x.shape[0] // rows
        if n_blocks % spec.streams:
            raise BenchSpecError(
                f"streams {spec.streams} does not divide {n_blocks} blocks")
        fn = mb_ops.make_timed_kernel(
            mix.name, depth=mix.fma_depth or 8, block_rows=rows,
            streams=spec.streams, interpret=spec.interpret, passes=passes)
        if mix.name == "triad":
            y = x * 0.5
            return lambda: fn(x, y)
        return lambda: fn(x)


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _BACKENDS[backend.name] = backend
    return backend


register_backend(XLABackend())
register_backend(PallasBackend())


def get_backend(name: str) -> Backend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)
