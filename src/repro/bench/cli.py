"""``python -m repro.bench`` — run / list-mixes / compare / characterize /
launch.

    run         execute a BenchSpec (flags or --spec JSON), print + save the
                schema-versioned result JSON; under a multi-process launch
                (REPRO_NUM_PROCESSES et al.) it initializes jax.distributed,
                gathers timings across processes, and saves from process 0
    list-mixes  the shared mix registry with its bytes/flops accounting
    compare     the same spec on several backends, side by side
    characterize  adaptive fine-granularity sweep -> detected topology ->
                FittedMachineModel JSON + markdown report (repro.characterize)
    istream     instruction-stream microscope: unroll x interleave sweep ->
                compiled-HLO instruction profiles -> bandwidth-vs-issue-bound
                classification + fig6 table (repro.istream)
    audit       static accounting verifier: declared bytes/flops vs compiled
                IR for every mix x backend x knob combination, no timing;
                exit 0 clean, 2 on violation (repro.audit)
    latency     loaded-latency surface: the latency_chase probe across the
                load axis -> bandwidth-latency curve + per-level knee fit
                (characterize.loaded); --smoke is the CI fast-fail gate
    launch      spawn N coordinated local processes running ``run --backend
                distributed`` with forced host devices — the single-machine
                simulation of a multi-host Fig-4 scaling study
    history     list the persistent run ledger (BENCH_history/); --add
                ingests a saved result JSON as a record (repro.obs.ledger)
    diff        noise-aware bandwidth comparison against a ledger baseline
                (characterize.detect two-sample test); exit 2 on regression

Measuring commands take ``--trace PATH`` (span tracing -> Perfetto JSON),
append a ledger record unless ``--no-ledger``, and refuse to overwrite an
existing ``--out``/``--report`` file unless ``--force``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.bench.mixes import registry
from repro.bench.runner import Runner
from repro.bench.spec import BenchSpec, BenchSpecError, quick_spec
from repro.obs import ledger, trace


def _parse_sizes(s: str) -> tuple[int, ...]:
    """'32768,1M,16M' -> bytes (supports K/M/G suffixes)."""
    out = []
    for tok in s.split(","):
        tok = tok.strip()
        mult = {"K": 2**10, "M": 2**20, "G": 2**30}.get(tok[-1:].upper(), 1)
        out.append(int(float(tok[:-1]) * mult) if mult != 1 else int(tok))
    return tuple(out)


def _spec_from_args(args) -> BenchSpec:
    if args.spec:
        return BenchSpec.from_json(args.spec)
    kw = {}
    if args.mixes is not None:
        kw["mixes"] = tuple(args.mixes.split(","))
    if args.sizes is not None:
        kw["sizes"] = _parse_sizes(args.sizes)
    # `is not None`: an explicit 0 must reach BenchSpec validation, not be
    # silently treated as "flag absent"
    if args.reps is not None:
        kw["reps"] = args.reps
    if args.streams is not None:
        kw["streams"] = args.streams
    if args.devices is not None:
        kw["devices"] = args.devices
    if args.block_rows is not None:
        kw["block_rows"] = args.block_rows
    if args.dtype is not None:
        kw["dtype"] = args.dtype
    if args.unroll is not None:
        kw["unroll"] = args.unroll
    if args.interleave is not None:
        kw["interleave"] = args.interleave
    if getattr(args, "load", None) is not None:
        kw["load"] = args.load
    if args.quick:
        return quick_spec(backend=args.backend, **kw)
    return BenchSpec(backend=args.backend, **kw)


def _add_spec_flags(p: argparse.ArgumentParser):
    p.add_argument("--spec", default=None,
                   help="path to a BenchSpec JSON (overrides other flags)")
    p.add_argument("--quick", action="store_true",
                   help="small sizes / few reps smoke preset")
    p.add_argument("--backend", default="xla",
                   help="xla | sharded | distributed | pallas")
    p.add_argument("--mixes", "--mix", default=None,
                   help="comma list, e.g. load_sum,copy,rw_3to1")
    p.add_argument("--sizes", default=None, help="comma list, K/M/G ok: 32K,2M")
    p.add_argument("--reps", type=int, default=None)
    p.add_argument("--streams", type=int, default=None)
    p.add_argument("--devices", type=int, default=None,
                   help="mesh devices (multi-device backends, e.g. sharded)")
    p.add_argument("--block-rows", dest="block_rows", type=int, default=None)
    p.add_argument("--dtype", default=None)
    p.add_argument("--unroll", type=int, default=None,
                   help="per-pass unroll factor (istream knob)")
    p.add_argument("--interleave", type=int, default=None,
                   help="independent dependence chains (istream knob)")
    p.add_argument("--load", type=int, default=None,
                   help="co-scheduled bandwidth generators next to the "
                        "latency probe (latency_chase only; 0 = idle)")


def _add_grid_flags(p: argparse.ArgumentParser):
    """The knob-grid flags shared by the two compiled-IR commands
    (``istream`` sweeps the grid with timing, ``audit`` without) — one
    parser helper so the two surfaces cannot drift apart."""
    p.add_argument("--backends", "--backend", default=None,
                   help="comma list (default: xla,pallas)")
    p.add_argument("--mixes", "--mix", default=None,
                   help="comma list (default: per-command representative set)")
    p.add_argument("--sizes", default=None,
                   help="comma list, K/M/G ok: 64K,1M")
    p.add_argument("--unrolls", default=None,
                   help="comma list of unroll factors")
    p.add_argument("--interleaves", default=None,
                   help="comma list of chain counts")


def _add_obs_flags(p: argparse.ArgumentParser):
    """Observability flags shared by every measuring command (repro.obs)."""
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="enable span tracing; write a Perfetto-loadable "
                        "Chrome trace JSON (or .jsonl event log) here")
    p.add_argument("--force", action="store_true",
                   help="overwrite existing output files (refused otherwise)")
    p.add_argument("--no-ledger", dest="no_ledger", action="store_true",
                   help="skip appending this run to the history ledger")
    p.add_argument("--history-root", dest="history_root", default=None,
                   help=f"ledger directory (default: ${ledger.LEDGER_ENV} "
                        f"or {ledger.DEFAULT_ROOT}/)")


def _check_overwrite(args, *attrs: str) -> None:
    """Refuse to clobber an existing output file unless --force — checked
    BEFORE the (possibly minutes-long) measurement, not after."""
    for a in attrs:
        path = getattr(args, a, None)
        if path and os.path.exists(path) and not getattr(args, "force", False):
            raise BenchSpecError(
                f"refusing to overwrite existing {path!r}; pass --force")


def _obs_begin(args) -> None:
    if getattr(args, "trace", None):
        trace.configure(enabled=True, clear=True)


def _obs_finish(args, res, cmd: str) -> None:
    """Write the trace and append the run's ledger record (call on the
    primary process only — the distributed gather has already merged the
    other processes' events into this tracer)."""
    trace_path = None
    if getattr(args, "trace", None):
        tr = trace.get_tracer()
        trace_path = tr.write(args.trace)
        print(f"# saved trace ({len(tr.events())} events) -> {trace_path}")
    if not getattr(args, "no_ledger", False):
        path, rec = ledger.append_record(
            res, cmd=cmd, trace_path=trace_path,
            out_path=getattr(args, "out", None),
            root=getattr(args, "history_root", None))
        print(f"# ledger += {rec['spec_digest']} "
              f"({len(rec['curves'])} cells) -> {path}")


def cmd_run(args) -> int:
    # distributed init must precede the first jax.devices() call (spec
    # validation touches the backend registry's meshes); a no-op outside a
    # multi-process launch
    _check_overwrite(args, "out")
    from repro.bench import distributed as dist
    dist.ensure_initialized()
    _obs_begin(args)
    spec = _spec_from_args(args)
    res = dist.gather_result(Runner().run(spec))
    if not dist.is_primary():
        print(f"# process {dist.process_index()}/{dist.process_count()} "
              f"done ({len(res.points)} points gathered by process 0)")
        return 0
    _obs_finish(args, res, "run")
    text = res.to_json(args.out)
    if args.out:
        for p in res.points:
            print(f"{p.backend}/{p.mix}/{p.nbytes}B,{p.mean_s * 1e6:.2f},"
                  f"{p.gbps:.2f}GB/s")
        print(f"# saved {len(res.points)} points (schema v{res.schema_version})"
              f" -> {args.out}")
    else:
        print(text)
    return 0


def cmd_list_mixes(args) -> int:
    from repro.bench.mixes import MAX_RW, mix_names
    reg = registry()
    print(f"{'mix':10s} {'flops/elem':>10s} {'reads':>6s} {'writes':>6s}  "
          f"{'backends':16s} description")
    for name in mix_names():     # deterministic: family parameter, then name
        m = reg[name]
        print(f"{name:10s} {m.flops_per_elem:10.1f} {m.reads_per_elem:6.1f} "
              f"{m.writes_per_elem:6.1f}  {'+'.join(m.backends):16s} "
              f"{m.description}")
    print(f"# open-ended families: fma_k (any k >= 1), rw_RtoW "
          f"(any R, W in 1..{MAX_RW}); the table lists the canonical ladders")
    return 0


def cmd_compare(args) -> int:
    _check_overwrite(args, "out")
    backends = tuple(args.backends.split(","))
    if args.spec:
        spec = BenchSpec.from_json(args.spec)
    else:
        # the requested mix set may be runnable by only some of the backends
        # (e.g. load_only): construct the base spec against the first backend
        # that accepts it in full; Runner.compare filters per backend
        spec, err = None, None
        for b in backends:
            args.backend = b
            try:
                spec = _spec_from_args(args)
                break
            except BenchSpecError as e:
                err = e
        if spec is None:
            raise err or BenchSpecError("no runnable spec")
    results = Runner().compare(spec, backends=backends)
    print(f"{'mix':10s} {'nbytes':>12s} " +
          " ".join(f"{b + ' GB/s':>14s}" for b in results))
    rows: dict[tuple, dict] = {}
    for b, res in results.items():
        for p in res.points:
            rows.setdefault((p.mix, p.nbytes), {})[b] = p
    mismatch = False
    for (mix, nbytes), per in sorted(rows.items()):
        cells = [f"{per[b].gbps:14.2f}" if b in per else f"{'-':>14s}"
                 for b in results]
        print(f"{mix:10s} {nbytes:12d} " + " ".join(cells))
        acct = {(p.bytes_per_call, p.flops_per_call) for p in per.values()}
        if len(acct) > 1:
            mismatch = True
            print(f"  !! accounting mismatch for {mix}: {acct}")
    skipped = next(iter(results.values())).meta.get("skipped", {})
    for b, items in sorted(skipped.items()):
        for mix, reason in items:
            print(f"# skipped {b}/{mix}: {reason}")
    if args.out:
        json.dump({b: r.to_dict() for b, r in results.items()},
                  open(args.out, "w"), indent=2)
        print(f"# saved -> {args.out}")
    return 1 if mismatch else 0


def cmd_characterize(args) -> int:
    """Measurement-driven machine characterization: adaptive fine-granularity
    sweep -> change-point detection -> FittedMachineModel + report (see
    repro.characterize).  ``--smoke`` is the CI fast preset (coarse grid,
    one refinement round); ``--full`` the paper-grade sweep."""
    from repro.characterize import characterize, render_markdown, write_report
    from repro.core.machine_model import get_spec

    _check_overwrite(args, "out", "report")
    _obs_begin(args)
    kw: dict = dict(backend=args.backend, resolution=args.resolution,
                    max_rounds=args.max_rounds)
    if args.smoke:
        # copy drives detection: its store stream keeps the big-size points
        # memory-bound on every host we've measured, so the cache cliffs are
        # sharpest where the coarse grid is thinnest
        kw.update(lo=16 * 2**10, hi=64 * 2**20, coarse_per_decade=2,
                  resolution=max(args.resolution, 0.35), max_rounds=2,
                  reps=5, warmup=1, target_bytes=3e7)
        mixes: tuple = ("copy", "load_sum")
    elif args.full:
        kw.update(coarse_per_decade=4, reps=10, warmup=2, target_bytes=2e8,
                  hi=256 * 2**20)
        mixes = ("load_sum", "copy", "fma_1", "fma_2", "fma_8", "fma_32",
                 "fma_64")
    else:
        kw.update(coarse_per_decade=3, reps=5, warmup=1, target_bytes=5e7)
        mixes = ("load_sum", "copy", "fma_8", "fma_32")
    if args.mixes:
        mixes = tuple(args.mixes.split(","))
    if args.interpret is not None:
        kw["spec_kw"] = {"interpret": args.interpret}

    model, sweep = characterize(mixes=mixes, primary=mixes[0], **kw)
    _obs_finish(args, sweep.result, "characterize")
    documented = get_spec(args.compare) if args.compare else None
    print(render_markdown(model, sweep, documented))
    if args.out:
        model.to_json(args.out)
        print(f"# saved fitted model (schema v{model.schema_version}, "
              f"{len(model.levels)} levels) -> {args.out}")
    if args.report:
        write_report(model, args.report, sweep, documented)
        print(f"# saved report -> {args.report}")
    return 0


def cmd_istream(args) -> int:
    """Instruction-stream sweep + classification (see repro.istream): runs
    the unroll x interleave grid on the requested backends/mixes, extracts
    per-case compiled-IR profiles, labels every point bandwidth-bound vs
    issue-bound, and prints the fig6 table.  ``--smoke`` is the CI gate: it
    first runs the deterministic synthetic classifier self-test (must see
    BOTH labels), then a seconds-scale end-to-end sweep."""
    from repro.istream import run_istream, synthetic_check

    _check_overwrite(args, "out")
    _obs_begin(args)
    if args.smoke:
        chk = synthetic_check()
        print(f"# synthetic check: {chk['labels']} "
              f"(issue rate {chk['issue_rate']:.3e} elem-ops/s)")
        if not chk["ok"]:
            print("error: synthetic classifier check failed "
                  f"({chk})", file=sys.stderr)
            return 2
    model = None
    if args.model:
        from repro.characterize.fit import FittedMachineModel
        model = FittedMachineModel.from_json(args.model)
    kw: dict = dict(smoke=args.smoke, model=model)
    if args.backends:
        kw["backends"] = tuple(args.backends.split(","))
    if args.mixes:
        kw["mixes"] = tuple(args.mixes.split(","))
    if args.sizes:
        kw["sizes"] = _parse_sizes(args.sizes)
    if args.unrolls:
        kw["unrolls"] = tuple(int(u) for u in args.unrolls.split(","))
    if args.interleaves:
        kw["interleaves"] = tuple(int(i) for i in args.interleaves.split(","))
    if args.reps is not None:
        kw["reps"] = args.reps
    report = run_istream(**kw)
    _obs_finish(args, report.result, "istream")
    print(report.table)
    labels = report.labels
    if args.out:
        report.result.to_json(args.out)
        print(f"# saved {len(report.result.points)} classified points "
              f"(schema v{report.result.schema_version}) -> {args.out}")
    if args.smoke and (not labels.get("issue-bound")
                       or not labels.get("bandwidth-bound")):
        # the measured sweep may legitimately land one-sided on unusual
        # hosts; the smoke gate only demands the synthetic check (above)
        # prove both labels reachable, so just flag it
        print(f"# note: measured sweep was one-sided ({labels}); "
              f"synthetic check covered both labels")
    return 0


def cmd_audit(args) -> int:
    """Static accounting audit (see repro.audit): declared bytes/flops vs
    compiled-IR observation for every registered mix x backend x knob
    combination.  Exit 0 clean, 2 on any accounting violation (each named
    by its mix/backend/knob triple).  ``--goldens DIR`` audits compiled-HLO
    text fixtures instead of lowering (deviceless CI path);
    ``--write-goldens DIR`` regenerates those fixtures."""
    from repro.audit import (audit_goldens, audit_registry, write_goldens)

    _check_overwrite(args, "out")
    if args.write_goldens:
        manifest = write_goldens(args.write_goldens)
        print(f"# wrote {len(manifest['cases'])} golden HLO fixtures "
              f"-> {args.write_goldens}")
        return 0
    if args.goldens:
        report = audit_goldens(args.goldens)
    else:
        kw: dict = dict(smoke=args.smoke, rw_pairs=args.rw_pairs,
                        seed=args.seed)
        if args.backends:
            kw["backends"] = tuple(args.backends.split(","))
        if args.mixes:
            kw["mixes"] = tuple(args.mixes.split(","))
        if args.sizes:
            nbytes = _parse_sizes(args.sizes)[0]
            kw["shape"] = (max(nbytes // (128 * 4), 8), 128)
        grid = None
        if args.unrolls or args.interleaves:
            grid = [{}]
            grid += [{"unroll": int(u)}
                     for u in (args.unrolls or "").split(",") if u and int(u) > 1]
            grid += [{"interleave": int(i)}
                     for i in (args.interleaves or "").split(",")
                     if i and int(i) > 1]
        if grid is not None:
            kw["knob_grid"] = grid
        report = audit_registry(**kw)
    if args.json:
        print(report.to_json())
    else:
        print(report.table())
    if args.out:
        report.to_json(args.out)
        print(f"# saved audit report ({len(report.cases)} cases) "
              f"-> {args.out}")
    for v in report.violations:
        print(f"error: accounting violation at {v.where()}: "
              + "; ".join(f"{c.name}: {c.detail}" for c in v.failures),
              file=sys.stderr)
    return report.exit_code()


def cmd_latency(args) -> int:
    """Loaded-latency surface (see characterize.loaded): sweep the
    ``latency_chase`` probe across the ``load`` axis at each working-set
    size, fit the per-level bandwidth–latency knee, print the curve, save
    the schema-v5 result.  ``--smoke`` is the CI fast-fail preset: one
    small size, loads (0, 1, 2), plus an inline accounting audit of the
    chase on BOTH backends (idle and loaded) that must come back checked
    — never waived — and clean (exit 2 otherwise)."""
    from repro.characterize.loaded import fit_loaded, loaded_latency_sweep

    _check_overwrite(args, "out")
    _obs_begin(args)
    sizes = _parse_sizes(args.sizes) if args.sizes else \
        ((128 * 2**10,) if args.smoke else (128 * 2**10, 16 * 2**20))
    loads = tuple(int(tok) for tok in args.loads.split(",")) if args.loads \
        else ((0, 1, 2) if args.smoke else (0, 1, 2, 4))
    reps = args.reps if args.reps is not None else (3 if args.smoke else 5)
    res = loaded_latency_sweep(sizes, loads, backend=args.backend,
                               runner=Runner(), reps=reps)
    fit = fit_loaded(res)
    if fit:
        res.meta["loaded_latency"]["fit"] = fit

    print(f"{'nbytes':>12s} {'load':>4s} {'latency ns':>10s} {'gen GB/s':>9s}")
    for p in res.points:
        print(f"{p.nbytes:12d} {p.load:4d} {p.latency_ns:10.2f} "
              f"{p.gen_gbps:9.2f}")
    for name, knee in ((fit or {}).get("levels") or {}).items():
        print(f"# {name}: idle {knee['idle_latency_ns']:.1f} ns, knee at "
              f"load={knee['knee_load']} ({knee['knee_gen_gbps']:.2f} GB/s "
              f"generated), max {knee['max_latency_ns']:.1f} ns")

    rc = 0
    if args.smoke:
        from repro.audit import audit_case
        shape = (64, 128)
        nbytes = shape[0] * shape[1] * 4
        audits = []
        for backend in ("xla", "pallas"):
            for load in (0, 1):
                spec = BenchSpec(mixes=("latency_chase",), sizes=(nbytes,),
                                 backend=backend, passes=4, reps=2, warmup=0,
                                 load=load)
                a = audit_case(spec, "latency_chase", shape, "float32", 4)
                audits.append(a)
                print(f"# audit {a.where()}: "
                      f"{'waived' if a.waived else 'ok' if a.ok else 'FAIL'}")
        res.meta["audit"] = [a.to_dict() for a in audits]
        if any(a.waived or not a.ok for a in audits):
            print("error: latency_chase accounting must be checked clean on "
                  "both backends (got a waiver or violation)", file=sys.stderr)
            rc = 2
    _obs_finish(args, res, "latency")
    if args.out:
        res.to_json(args.out)
        print(f"# saved {len(res.points)} points "
              f"(schema v{res.schema_version}) -> {args.out}")
    return rc


def cmd_launch(args) -> int:
    """Spawn N coordinated local processes running ``run`` with the same
    spec flags (see bench.distributed.launch_local).  All children share one
    argv — ``cmd_run`` gates the ``--out`` write on process 0, which holds
    the gathered result; the others report and exit."""
    from repro.bench import distributed as dist
    if any(f == "--spec" or f.startswith("--spec=")
           for f in args.worker_flags):
        # a spec file short-circuits _spec_from_args, silently discarding
        # the injected --backend/--devices below — the workers would run
        # the file's backend single-process and the 'gathered' result would
        # be wrong; demand explicit flags instead
        raise BenchSpecError(
            "launch does not accept --spec (the file's backend/devices "
            "would override the injected distributed defaults); pass the "
            "spec as explicit flags (--mixes/--sizes/--devices/...)")
    worker = [sys.executable, "-m", "repro.bench", "run",
              "--backend", args.backend] + list(args.worker_flags)
    if not any(f == "--devices" or f.startswith("--devices=")
               for f in args.worker_flags):
        # default to the full simulated mesh: every process must own a mesh
        # shard (the backend rejects a mesh that leaves a process out).
        # Appended, so it must not shadow either user spelling — argparse
        # takes the LAST occurrence
        worker += ["--devices",
                   str(args.processes * args.devices_per_process)]
    if args.out:
        worker += ["--out", args.out]
    return dist.launch_local(worker, processes=args.processes,
                             devices_per_process=args.devices_per_process,
                             timeout=args.timeout or None)


def cmd_history(args) -> int:
    """List the persistent run ledger (see repro.obs.ledger).  ``--add``
    first ingests a file — a saved ledger record or a full BenchResult
    JSON (summarized on the fly), which is how CI folds the committed
    fig-artifact results into the history it diffs against."""
    root = args.history_root
    if args.add:
        rec = ledger.resolve_ref(args.add, root=root)
        path, rec = ledger.append_record(rec, root=root)
        print(f"# ledger += {rec['spec_digest']} "
              f"({len(rec.get('curves') or [])} cells) -> {path}")
    records = ledger.read_ledger(root)
    if args.json:
        print(json.dumps(records, indent=1))
        return 0
    if not records:
        print(f"# empty ledger at {ledger.ledger_root(root)}")
        return 0
    import datetime
    print(f"{'idx':>3s} {'when':19s} {'cmd':12s} {'digest':12s} "
          f"{'backend':11s} {'cells':>5s} mixes")
    for i, r in enumerate(records):
        t = datetime.datetime.fromtimestamp(r.get("time_unix_s", 0))
        print(f"{i:3d} {t:%Y-%m-%d %H:%M:%S} {r.get('cmd', '?'):12s} "
              f"{r.get('spec_digest', '?'):12s} "
              f"{str(r.get('backend') or '-'):11s} "
              f"{len(r.get('curves') or []):5d} "
              f"{','.join(r.get('mixes') or [])}")
    return 0


def cmd_diff(args) -> int:
    """Noise-aware bandwidth diff against a ledger baseline (see
    repro.obs.ledger.diff_records): per curve cell, the two-sample
    log-bandwidth test of ``characterize.detect.significant_step``.
    Exit 0 when nothing significantly dropped, 2 on regression."""
    root = args.history_root
    base = ledger.resolve_ref(args.baseline, root=root)
    cur = ledger.resolve_ref(args.current, root=root)
    report = ledger.diff_records(base, cur, z=args.z,
                                 tolerance=args.tolerance)
    if args.json:
        print(json.dumps(report.to_dict(), indent=1))
    else:
        print(report.table())
    for r in report.regressions:
        print(f"error: bandwidth regression at {r['cell']}: "
              f"{r['base_gbps']:.2f} -> {r['cur_gbps']:.2f} GB/s "
              f"(ratio {r['ratio']:.3f})", file=sys.stderr)
    return report.exit_code()


def main(argv=None) -> int:
    # allow_abbrev everywhere: `launch --devices 4` must reach the workers
    # as the spec's devices knob, not silently match --devices-per-process
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__, allow_abbrev=False)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="execute a BenchSpec",
                           allow_abbrev=False)
    _add_spec_flags(p_run)
    p_run.add_argument("--out", default=None, help="write result JSON here")
    _add_obs_flags(p_run)
    p_run.set_defaults(fn=cmd_run)

    p_list = sub.add_parser("list-mixes", help="show the mix registry")
    p_list.set_defaults(fn=cmd_list_mixes)

    p_cmp = sub.add_parser("compare", help="same spec on several backends",
                           allow_abbrev=False)
    _add_spec_flags(p_cmp)
    p_cmp.add_argument("--backends", default="xla,pallas")
    p_cmp.add_argument("--out", default=None)
    p_cmp.add_argument("--force", action="store_true",
                       help="overwrite an existing --out file")
    p_cmp.set_defaults(fn=cmd_compare)

    p_chz = sub.add_parser(
        "characterize",
        help="adaptive sweep -> detected topology -> fitted machine model",
        allow_abbrev=False)
    mode = p_chz.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="CI preset: coarse grid, minimal refinement")
    mode.add_argument("--full", action="store_true",
                      help="paper-grade sweep (slow)")
    p_chz.add_argument("--backend", default="xla",
                       help="measurement backend (xla | pallas | sharded)")
    p_chz.add_argument("--resolution", type=float, default=0.10,
                       help="target relative width of capacity brackets")
    p_chz.add_argument("--max-rounds", dest="max_rounds", type=int, default=8)
    p_chz.add_argument("--mixes", "--mix", default=None,
                       help="comma list; first is the detection-driving mix")
    p_chz.add_argument("--interpret", type=lambda s: s.lower() != "false",
                       default=None, help="Pallas interpret mode override")
    p_chz.add_argument("--compare", default=None,
                       help="documented spec to diff against (e.g. "
                            "fujitsu-a64fx, host)")
    p_chz.add_argument("--out", default=None,
                       help="write the FittedMachineModel JSON here")
    p_chz.add_argument("--report", default=None,
                       help="write a markdown (.md) or JSON (.json) report")
    _add_obs_flags(p_chz)
    p_chz.set_defaults(fn=cmd_characterize)

    p_ist = sub.add_parser(
        "istream",
        help="unroll x interleave sweep -> compiled-IR profiles -> "
             "bandwidth-vs-issue-bound classification (fig6)",
        allow_abbrev=False)
    p_ist.add_argument("--smoke", action="store_true",
                       help="CI gate: synthetic classifier self-test + "
                            "seconds-scale end-to-end sweep")
    _add_grid_flags(p_ist)
    p_ist.add_argument("--reps", type=int, default=None)
    p_ist.add_argument("--model", default=None,
                       help="FittedMachineModel JSON for bandwidth lookup "
                            "(else self-calibrated from the sweep)")
    p_ist.add_argument("--out", default=None,
                       help="write the classified result JSON here")
    _add_obs_flags(p_ist)
    p_ist.set_defaults(fn=cmd_istream)

    p_aud = sub.add_parser(
        "audit",
        help="declared vs compiled accounting verification (exit 2 on "
             "violation; see repro.audit)",
        allow_abbrev=False)
    p_aud.add_argument("--smoke", action="store_true",
                       help="CI fast-fail: representative mixes, base knobs")
    _add_grid_flags(p_aud)
    p_aud.add_argument("--rw-pairs", dest="rw_pairs", type=int, default=0,
                       help="additionally audit N random rw_RtoW members")
    p_aud.add_argument("--seed", type=int, default=0,
                       help="seed for --rw-pairs sampling")
    p_aud.add_argument("--goldens", default=None,
                       help="audit compiled-HLO fixtures in this directory "
                            "(deviceless; e.g. tests/data/hlo)")
    p_aud.add_argument("--write-goldens", dest="write_goldens", default=None,
                       help="regenerate the golden HLO fixtures here")
    p_aud.add_argument("--json", action="store_true",
                       help="print the full JSON report instead of the table")
    p_aud.add_argument("--out", default=None,
                       help="write the audit report JSON here")
    p_aud.add_argument("--force", action="store_true",
                       help="overwrite an existing --out file")
    p_aud.set_defaults(fn=cmd_audit)

    p_lat = sub.add_parser(
        "latency",
        help="loaded-latency surface: latency_chase across the load axis "
             "(Mess-style bandwidth-latency curves; see characterize.loaded)",
        allow_abbrev=False)
    p_lat.add_argument("--smoke", action="store_true",
                       help="CI fast-fail: one small size, loads 0,1,2, plus "
                            "an inline both-backend chase accounting audit")
    p_lat.add_argument("--backend", default="xla",
                       help="xla | pallas (single-device time-shared "
                            "composite; sharded sweeps need explicit "
                            "--devices per load, use `run`)")
    p_lat.add_argument("--sizes", default=None,
                       help="comma list, K/M/G ok (default: 128K smoke, "
                            "128K,16M full)")
    p_lat.add_argument("--loads", default=None,
                       help="comma list of generator counts "
                            "(default: 0,1,2 smoke, 0,1,2,4 full)")
    p_lat.add_argument("--reps", type=int, default=None)
    p_lat.add_argument("--out", default=None,
                       help="write the result JSON here")
    _add_obs_flags(p_lat)
    p_lat.set_defaults(fn=cmd_latency)

    p_launch = sub.add_parser(
        "launch", help="N coordinated local processes (multi-host simulation)",
        allow_abbrev=False)
    p_launch.add_argument("--processes", type=int, default=2,
                          help="simulated hosts (one process each)")
    p_launch.add_argument("--devices-per-process", dest="devices_per_process",
                          type=int, default=1,
                          help="forced host devices per process; the global "
                               "mesh has processes * this many devices")
    p_launch.add_argument("--backend", default="distributed",
                          help="worker backend (default: distributed)")
    p_launch.add_argument("--timeout", type=float, default=None,
                          help="seconds before stragglers are killed")
    p_launch.add_argument("--out", default=None,
                          help="gathered result JSON (written by process 0)")
    p_launch.set_defaults(fn=cmd_launch, takes_worker_flags=True)

    p_hist = sub.add_parser(
        "history", help="list the persistent run ledger (repro.obs.ledger)",
        allow_abbrev=False)
    p_hist.add_argument("--add", default=None, metavar="FILE",
                        help="ingest a saved result/record JSON as a ledger "
                             "record first")
    p_hist.add_argument("--history-root", dest="history_root", default=None,
                        help=f"ledger directory (default: "
                             f"${ledger.LEDGER_ENV} or {ledger.DEFAULT_ROOT}/)")
    p_hist.add_argument("--json", action="store_true",
                        help="print raw records instead of the table")
    p_hist.set_defaults(fn=cmd_history)

    p_diff = sub.add_parser(
        "diff", help="noise-aware bandwidth diff vs a ledger baseline "
                     "(exit 2 on regression)",
        allow_abbrev=False)
    p_diff.add_argument("--baseline", required=True,
                        help="ledger index (-1 = newest), 'latest', a spec-"
                             "digest prefix, or a record/result JSON file")
    p_diff.add_argument("--current", default="latest",
                        help="same forms (default: latest)")
    p_diff.add_argument("--z", type=float, default=3.0,
                        help="noise-test z score (detect.significant_step)")
    p_diff.add_argument("--tolerance", type=float, default=0.05,
                        help="minimum relative drop treated as real")
    p_diff.add_argument("--history-root", dest="history_root", default=None,
                        help=f"ledger directory (default: "
                             f"${ledger.LEDGER_ENV} or {ledger.DEFAULT_ROOT}/)")
    p_diff.add_argument("--json", action="store_true",
                        help="print the full diff report JSON")
    p_diff.set_defaults(fn=cmd_diff)

    # `launch` forwards unknown flags (--mixes/--sizes/--devices/...) to its
    # `run` workers verbatim; every other command treats extras as errors
    args, extra = ap.parse_known_args(argv)
    if getattr(args, "takes_worker_flags", False):
        args.worker_flags = extra
    elif extra:
        ap.error(f"unrecognized arguments: {' '.join(extra)}")
    try:
        return args.fn(args)
    except (BenchSpecError, ValueError, KeyError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
