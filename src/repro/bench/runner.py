"""The Runner — pass-picking, warmup, serialized timing, result assembly.

This is the ONE measurement loop in the repo.  The figure scripts, the legacy
``core.sweep`` / ``core.scaling`` wrappers, the autotuner, and the CLI all
hand it a BenchSpec; it owns the repetition discipline (warmup + reps via
``core.timing``), the pass-picking policy (enough internal passes that one
timed call moves ``target_bytes`` — the paper's measurement-loop sizing), and
emits a schema-versioned BenchResult.

Memory discipline: working sets are built lazily, one size at a time, and
released as soon as that size's cases are timed — peak footprint is one
working set (plus companions, e.g. triad's second stream), not the sum of
every size in the sweep.  Compiled cases are cached per Runner instance,
keyed by (backend, mix, shape, dtype, passes, knobs): a knob sweep via
``run_many`` or a ``compare`` re-times cached kernels instead of re-tracing
them, and a cached case never closes over a buffer (see bench.backends).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.bench.backends import get_backend
from repro.bench.result import (REP_SAMPLE_LIMIT, BenchPoint, BenchResult,
                                machine_meta)
from repro.bench.spec import BenchSpec, BenchSpecError
from repro.obs import metrics, trace


#: serial dependent-load steps per timed call for chase mixes — the latency
#: analogue of ``target_bytes``.  A pointer chase is ~2 orders of magnitude
#: slower per byte than a bandwidth sweep (each load waits out the full
#: access latency), so sizing its passes by target_bytes over-provisions the
#: wall time of a timed call by the same factor; size by total chain steps
#: instead.
CHASE_TARGET_STEPS = 2 ** 17


def pick_passes(nbytes: int, target_bytes: float = 2e8, mix=None,
                n_elems: int | None = None, devices: int = 1) -> int:
    """Enough passes that one timed call moves ~target_bytes (>= ms-scale).

    Chase mixes are sized per-mix instead: enough passes that one call walks
    ~``CHASE_TARGET_STEPS`` dependent steps (per probe shard — on a mesh the
    probe walks its ``1/devices`` slice), because a dependent chain's wall
    time scales with steps x latency, not bytes / bandwidth."""
    if mix is not None and getattr(mix, "chase", False):
        steps = max(1, (n_elems if n_elems else nbytes // 4)
                    // max(devices, 1))
        return max(1, CHASE_TARGET_STEPS // steps)
    return max(1, int(target_bytes / max(nbytes, 1)))


def _chase_accounting(mix, spec: BenchSpec, real_bytes: int, n_elems: int,
                      passes: int) -> tuple[float, float]:
    """Bytes/flops per timed call for a chase (latency-probe) case.

    Probe traffic: idle (load=0) every shard walks its own cycle, touching
    the whole buffer per pass; in a loaded composite only shard 0 walks its
    ``1/devices`` slice (devices=1 on the single-device backends).
    Generator traffic: each of the ``load`` generators performs
    ``GEN_SWEEPS_PER_PASS`` load_sum sweeps of its ``1/devices`` slice per
    probe pass — the same formula both backends' composite kernels execute,
    so the bytes_per_call a chase point reports is total composite traffic
    (probe + generators).  Flops: the probe does none; each generator
    element costs one load_sum add."""
    from repro.bench.mixes import GEN_SWEEPS_PER_PASS
    k = max(spec.devices, 1)
    probe_bytes = mix.bytes_per_pass(real_bytes) / (k if spec.load else 1)
    gen_elems = spec.load * GEN_SWEEPS_PER_PASS * (n_elems / k)
    gen_bytes = gen_elems * (real_bytes / n_elems)
    return (probe_bytes + gen_bytes) * passes, gen_elems * passes


class Runner:
    """Executes BenchSpecs.  Stateless apart from the backend registry and
    the compiled-case cache (kernels only — never working-set buffers)."""

    def __init__(self):
        self._cases: dict[tuple, object] = {}   # case_key -> compiled case
        self.cache_hits = 0
        self.cache_misses = 0

    # -- compiled-case cache --------------------------------------------
    def _case(self, backend, spec: BenchSpec, mix, shape, dtype, passes: int):
        """Cache-aware make_case; returns the compiled callable-of-buffers.
        Every lookup emits a ``cache`` trace event with its outcome and
        bumps the matching obs counter — the result's ``meta["obs"]``
        counters and the trace agree by construction."""
        tr = trace.get_tracer()
        key = backend.case_key(spec, mix, shape, dtype, passes)
        case = self._cases.get(key)
        if case is None:
            self.cache_misses += 1
            metrics.REGISTRY.inc("cache_misses")
            tr.event("cache", outcome="miss", mix=mix.name,
                     backend=backend.name)
            with tr.span("case.build", mix=mix.name, backend=backend.name,
                         passes=passes):
                case = backend.make_case(spec, mix, shape, dtype, passes)
            self._cases[key] = case
        else:
            self.cache_hits += 1
            metrics.REGISTRY.inc("cache_hits")
            tr.event("cache", outcome="hit", mix=mix.name,
                     backend=backend.name)
        return case

    def run(self, spec: BenchSpec, extra_meta: dict | None = None
            ) -> BenchResult:
        """Execute one spec.  Observability (repro.obs): the whole run is a
        ``runner.run`` span with ``runner.plan`` and per-size ``runner.size``
        children (buffer build/release, per-case timing), the obs counter
        registry collects this run's delta (cache outcomes, buffer
        lifecycle, peak working set), and both land in ``meta["obs"]``
        (result schema v6) together with the Runner's cumulative cache
        counters — which previously died with the Runner object."""
        tr = trace.get_tracer()
        with metrics.REGISTRY.scope() as mscope, \
                tr.span("runner.run", backend=spec.backend,
                        mixes=list(spec.mixes), sizes=list(spec.sizes),
                        devices=spec.devices):
            res = self._run_traced(spec, extra_meta, tr)
            obs = mscope.delta()
            # THIS run's peak, not the scope delta: the global gauge is a
            # process-lifetime high-water mark, so a run smaller than an
            # earlier one would otherwise report no peak at all
            if res.points:
                obs.setdefault("gauges", {})["peak_working_set_bytes"] = \
                    max(p.nbytes for p in res.points)
            obs["runner"] = {"cache_hits": self.cache_hits,
                             "cache_misses": self.cache_misses}
            res.meta["obs"] = obs
        return res

    def _run_traced(self, spec: BenchSpec, extra_meta, tr) -> BenchResult:
        from repro.bench.mixes import get_mix
        from repro.core import buffers, timing

        # plan every case up front from shapes alone (no buffers yet): a
        # data-dependent knob error (block_rows / streams / devices not
        # dividing some size) surfaces before any timing is spent, and the
        # compiled-case cache is populated without retaining working sets.
        # (build()-only third-party backends get no shape pre-check — their
        # data-dependent errors surface lazily, when their size is reached)
        plan = []       # (nbytes, shape, [(mix, passes, case|None, bpc, fpc)])
        dtype = jnp.dtype(spec.dtype)
        with tr.span("runner.plan", sizes=len(spec.sizes),
                     mixes=len(spec.mixes)):
            backend = get_backend(spec.backend)
            backend.validate(spec)
            cacheable = hasattr(backend, "make_case")
            for nbytes in spec.sizes:
                shape = buffers.working_set_shape(nbytes, dtype=dtype)
                n_elems = shape[0] * shape[1]
                real_bytes = n_elems * dtype.itemsize
                group = []
                for name in spec.mixes:
                    mix = get_mix(name)
                    # per-MIX pass picking: a chase mix is sized by chain
                    # steps, a bandwidth mix by bytes (same answer for
                    # uniform specs)
                    passes = spec.passes or pick_passes(
                        real_bytes, spec.target_bytes, mix=mix,
                        n_elems=n_elems, devices=spec.devices)
                    if passes % spec.unroll:
                        # auto-picked passes round UP to whole unrolled loop
                        # bodies (explicit spec.passes is validated to divide)
                        passes += spec.unroll - passes % spec.unroll
                    case = (self._case(backend, spec, mix, shape, dtype,
                                       passes)
                            if cacheable else None)
                    if mix.chase:
                        bpc, fpc = _chase_accounting(mix, spec, real_bytes,
                                                     n_elems, passes)
                    else:
                        bpc = mix.bytes_per_pass(real_bytes) * passes
                        fpc = mix.flops_per_pass(n_elems) * passes
                    group.append((mix, passes, case, bpc, fpc))
                plan.append((real_bytes, shape, group))

        with tr.span("runner.meta"):    # machine_meta touches jax.devices()
            res = BenchResult(
                spec=spec.to_dict(), machine=machine_meta(),
                meta={"dtype": spec.dtype, "reps": spec.reps,
                      "sizes": list(spec.sizes), "mixes": list(spec.mixes),
                      **(extra_meta or {})})
        prepare = getattr(backend, "prepare_buffer", None)
        for nbytes, (real_bytes, shape, group) in zip(spec.sizes, plan):
            with tr.span("runner.size", nbytes=real_bytes):
                # lazy build: exactly one working set lives at a time
                with tr.span("buffers.build", nbytes=real_bytes):
                    x = buffers.working_set(nbytes, dtype=dtype,
                                            value=spec.value)
                    if prepare is not None:  # e.g. sharded: one mesh
                        x = prepare(spec, x)  # placement, shared per size
                metrics.REGISTRY.inc("buffers_built")
                metrics.REGISTRY.gauge_max("peak_working_set_bytes",
                                           real_bytes)
                for mix, passes, case, bpc, fpc in group:
                    with tr.span("runner.case", mix=mix.name, passes=passes,
                                 reps=spec.reps):
                        if case is not None:
                            fn = backend.bind_case(case, spec, mix, x)
                        else:
                            fn = backend.build(spec, mix, x, passes)
                        t = timing.time_fn(fn, reps=spec.reps,
                                           warmup=spec.warmup,
                                           bytes_per_call=bpc,
                                           flops_per_call=fpc)
                        del fn  # drop companions with the case binding
                    latency_ns = gen_gbps = None
                    if mix.chase:
                        # the Mess-curve coordinates: ns per dependent step
                        # of the probe shard's walk, and aggregate generator
                        # GB/s
                        from repro.bench.mixes import GEN_SWEEPS_PER_PASS
                        k = max(spec.devices, 1)
                        n_elems = shape[0] * shape[1]
                        steps = passes * max(n_elems // k, 1)
                        latency_ns = t.mean_s * 1e9 / steps
                        gen_bytes = (spec.load * GEN_SWEEPS_PER_PASS
                                     * real_bytes / k) * passes
                        gen_gbps = gen_bytes / t.mean_s / 1e9
                    res.points.append(BenchPoint(
                        nbytes=real_bytes, nbytes_requested=nbytes,
                        mix=mix.name, dtype=spec.dtype,
                        backend=spec.backend, passes=passes,
                        streams=spec.streams,
                        block_rows=spec.block_rows, reps=spec.reps,
                        bytes_per_call=bpc, flops_per_call=fpc,
                        mean_s=t.mean_s, std_s=t.std_s, min_s=t.min_s,
                        gbps=t.gbps, gflops=t.gflops, devices=spec.devices,
                        unroll=spec.unroll, interleave=spec.interleave,
                        load=spec.load, latency_ns=latency_ns,
                        gen_gbps=gen_gbps,
                        rep_times_s=t.samples(REP_SAMPLE_LIMIT)))
                del x       # release this size before building the next
                metrics.REGISTRY.inc("buffers_released")
                tr.event("buffers.release", nbytes=real_bytes)
        return res

    def run_many(self, specs, extra_meta: dict | None = None) -> BenchResult:
        """Run several specs into one result (e.g. a streams / block_rows /
        devices sweep, where the knob lives on the spec rather than the point
        list).  With more than one distinct spec the envelope records all of
        them (``spec["many"]``) and the meta knob lists (``sizes``/``mixes``)
        are the union across the merged specs; each point carries its own
        knobs regardless.  Compiled cases are shared across the specs (the
        Runner-level cache), so sweeping a knob re-traces nothing that
        already compiled."""
        results = [self.run(s, extra_meta=extra_meta) for s in specs]
        if not results:
            raise ValueError("run_many needs at least one spec")
        merged = results[0]
        for r in results[1:]:
            merged.points.extend(r.points)
        # the envelope must describe ALL merged points, not results[0]'s
        merged.meta["sizes"] = sorted({s for r in results
                                       for s in r.meta["sizes"]})
        mixes: list[str] = []
        for r in results:
            mixes.extend(m for m in r.meta["mixes"] if m not in mixes)
        merged.meta["mixes"] = mixes
        # dtype/reps likewise: results[0]'s scalar silently misdescribed a
        # merge of disagreeing specs — stay scalar when uniform (the common
        # knob sweep), union to a first-seen-ordered list when not (each
        # point still carries its own dtype/reps regardless)
        for key in ("dtype", "reps"):
            vals: list = []
            for r in results:
                v = r.meta[key]
                for item in (v if isinstance(v, list) else [v]):
                    if item not in vals:
                        vals.append(item)
            merged.meta[key] = vals[0] if len(vals) == 1 else vals
        # obs counters fold across the merged runs (sum counters, max
        # gauges); the Runner-cumulative block already spans them all
        merged.meta["obs"] = metrics.merge_obs(
            [r.meta["obs"] for r in results if "obs" in r.meta])
        spec_dicts = [r.spec for r in results]
        if any(d != spec_dicts[0] for d in spec_dicts[1:]):
            merged.spec = {"spec_version": spec_dicts[0]["spec_version"],
                           "many": spec_dicts}
        return merged

    def compare(self, spec: BenchSpec, backends=("xla", "pallas")
                ) -> dict[str, BenchResult]:
        """The same spec on several backends — the paper's
        oracle-vs-embodiment cross-check.  Mixes are filtered per backend by
        *full* validation (support set and knob combinations), so e.g.
        ``streams=4`` keeps load_sum on xla and drops copy rather than
        aborting the whole comparison.  Nothing is dropped silently: every
        skipped (backend, mix) lands in each result's
        ``meta["skipped"] = {backend: [[mix, reason], ...]}``, and if *no*
        backend can run the spec the skip map is raised as a BenchSpecError
        instead of returning an empty dict."""
        out: dict[str, BenchResult] = {}
        skipped: dict[str, list[list[str]]] = {}
        for b in backends:
            names = []
            for m in spec.mixes:
                try:
                    sub = spec.replace(backend=b, mixes=(m,))
                    get_backend(b).validate(sub)
                except (BenchSpecError, KeyError) as e:
                    skipped.setdefault(b, []).append([m, str(e)])
                    continue
                names.append(m)
            if not names:
                continue
            try:
                out[b] = self.run(spec.replace(backend=b, mixes=tuple(names)))
            except BenchSpecError as e:
                # data-dependent constraint (e.g. streams vs. block count for
                # this buffer): this backend can't run the spec — record it
                skipped.setdefault(b, []).extend([m, str(e)] for m in names)
                continue
        if not out:
            raise BenchSpecError(f"no backend could run the spec; "
                                 f"skipped: {skipped}")
        if skipped:
            for res in out.values():
                res.meta["skipped"] = skipped
        return out


def run(spec: BenchSpec, **kw) -> BenchResult:
    """Module-level convenience: ``repro.bench.run(spec)``."""
    return Runner().run(spec, **kw)
