"""The Runner — pass-picking, warmup, serialized timing, result assembly.

This is the ONE measurement loop in the repo.  The figure scripts, the legacy
``core.sweep`` wrapper, the autotuner, and the CLI all hand it a BenchSpec;
it owns the repetition discipline (warmup + reps via ``core.timing``), the
pass-picking policy (enough internal passes that one timed call moves
``target_bytes`` — the paper's measurement-loop sizing), and emits a
schema-versioned BenchResult.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.bench.backends import get_backend
from repro.bench.result import BenchPoint, BenchResult, machine_meta
from repro.bench.spec import BenchSpec, BenchSpecError


def pick_passes(nbytes: int, target_bytes: float = 2e8) -> int:
    """Enough passes that one timed call moves ~target_bytes (>= ms-scale)."""
    return max(1, int(target_bytes / max(nbytes, 1)))


class Runner:
    """Executes BenchSpecs.  Stateless apart from the backend registry (and a
    buffer cache scoped to a run_many call)."""

    def __init__(self):
        self._buffers: dict | None = None   # (nbytes, dtype, value) -> array

    def _working_set(self, spec: BenchSpec, nbytes: int):
        from repro.core import buffers
        key = (nbytes, spec.dtype, spec.value)
        if self._buffers is not None and key in self._buffers:
            return self._buffers[key]
        x = buffers.working_set(nbytes, dtype=jnp.dtype(spec.dtype),
                                value=spec.value)
        if self._buffers is not None:
            self._buffers[key] = x
        return x

    def run(self, spec: BenchSpec, extra_meta: dict | None = None
            ) -> BenchResult:
        from repro.core import timing
        backend = get_backend(spec.backend)
        backend.validate(spec)
        from repro.bench.mixes import get_mix

        # build every case first: a data-dependent knob error (block_rows /
        # streams not dividing some size) surfaces before any timing is spent
        cases = []
        for nbytes in spec.sizes:
            x = self._working_set(spec, nbytes)
            real_bytes = x.size * x.dtype.itemsize
            passes = spec.passes or pick_passes(real_bytes, spec.target_bytes)
            for name in spec.mixes:
                mix = get_mix(name)
                fn = backend.build(spec, mix, x, passes)
                bpc = mix.bytes_per_pass(real_bytes) * passes
                fpc = mix.flops_per_pass(x.size) * passes
                cases.append((real_bytes, x, name, passes, fn, bpc, fpc))

        res = BenchResult(
            spec=spec.to_dict(), machine=machine_meta(),
            meta={"dtype": spec.dtype, "reps": spec.reps,
                  "sizes": list(spec.sizes), "mixes": list(spec.mixes),
                  **(extra_meta or {})})
        for real_bytes, x, name, passes, fn, bpc, fpc in cases:
            t = timing.time_fn(fn, reps=spec.reps, warmup=spec.warmup,
                               bytes_per_call=bpc, flops_per_call=fpc)
            res.points.append(BenchPoint(
                nbytes=real_bytes, mix=name, dtype=spec.dtype,
                backend=spec.backend, passes=passes, streams=spec.streams,
                block_rows=spec.block_rows, reps=spec.reps,
                bytes_per_call=bpc, flops_per_call=fpc,
                mean_s=t.mean_s, std_s=t.std_s, min_s=t.min_s,
                gbps=t.gbps, gflops=t.gflops))
        return res

    def run_many(self, specs, extra_meta: dict | None = None) -> BenchResult:
        """Run several specs into one result (e.g. a streams or block_rows
        sweep, where the knob lives on the spec rather than the point list).
        With more than one distinct spec the envelope records all of them
        (``spec["many"]``); each point carries its own knobs regardless.
        Working-set buffers are shared across the specs, so sweeping a knob
        does not re-initialize every buffer per knob value."""
        fresh = self._buffers is None
        if fresh:
            self._buffers = {}
        try:
            results = [self.run(s, extra_meta=extra_meta) for s in specs]
        finally:
            if fresh:
                self._buffers = None
        if not results:
            raise ValueError("run_many needs at least one spec")
        merged = results[0]
        for r in results[1:]:
            merged.points.extend(r.points)
        spec_dicts = [r.spec for r in results]
        if any(d != spec_dicts[0] for d in spec_dicts[1:]):
            merged.spec = {"spec_version": spec_dicts[0]["spec_version"],
                           "many": spec_dicts}
        return merged

    def compare(self, spec: BenchSpec, backends=("xla", "pallas")
                ) -> dict[str, BenchResult]:
        """The same spec on several backends — the paper's
        oracle-vs-embodiment cross-check.  Mixes are filtered per backend by
        *full* validation (support set and knob combinations), so e.g.
        ``streams=4`` keeps load_sum on xla and drops copy rather than
        aborting the whole comparison."""
        out = {}
        for b in backends:
            names = []
            for m in spec.mixes:
                try:
                    sub = spec.replace(backend=b, mixes=(m,))
                    get_backend(b).validate(sub)
                except (BenchSpecError, KeyError):
                    continue
                names.append(m)
            if not names:
                continue
            try:
                out[b] = self.run(spec.replace(backend=b, mixes=tuple(names)))
            except BenchSpecError:
                # data-dependent constraint (e.g. streams vs. block count for
                # this buffer): this backend can't run the spec — skip it
                continue
        return out


def run(spec: BenchSpec, **kw) -> BenchResult:
    """Module-level convenience: ``repro.bench.run(spec)``."""
    return Runner().run(spec, **kw)
