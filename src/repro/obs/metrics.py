"""Counter/gauge registry — the numbers the trace's events add up to.

Stdlib-only and always on: increments are dict operations under one lock,
all of them on setup/teardown paths (plan, buffer build/release, cache
lookup, launcher supervision) — never inside the timed repetition loop, so
the measurement discipline is untouched.

Canonical counter names (what ``BenchResult.meta["obs"]`` carries — the
set is open, these are the ones the built-in instrumentation emits):

    cache_hits / cache_misses      Runner compiled-case cache outcomes
    buffers_built / buffers_released   lazy working-set lifecycle
    audit_waivers                  audit cases reported-but-not-checked
    straggler_kills                launcher processes killed after a peer
                                   failure or timeout
    adaptive_rounds                characterize refinement rounds driven

Gauges:

    peak_working_set_bytes         high-water resident working set (the
                                   Runner's one-size-at-a-time discipline,
                                   made observable)

``Runner.run`` wraps itself in ``REGISTRY.scope()`` and stores the *delta*
(what this run did, not process-lifetime totals) into
``meta["obs"]["counters"]`` / ``["gauges"]`` — so the counters match the
run's own trace events one-for-one, which the obs CI gate asserts.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager


class MetricsRegistry:
    """Named monotonically increasing counters + last/high-water gauges."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}

    # -- recording ----------------------------------------------------------
    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def gauge_max(self, name: str, value: float) -> None:
        """High-water gauge: keeps the max ever seen (e.g. peak bytes)."""
        with self._lock:
            if value > self._gauges.get(name, float("-inf")):
                self._gauges[name] = value

    # -- reading ------------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges)}

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()

    @contextmanager
    def scope(self):
        """Yields a handle whose ``.delta()`` is the counter increments (and
        gauge values touched) since the scope opened — per-run accounting on
        a shared registry."""
        before = self.snapshot()
        handle = _Scope(self, before)
        yield handle

    def delta_since(self, before: dict) -> dict:
        after = self.snapshot()
        counters = {}
        for k, v in after["counters"].items():
            d = v - before["counters"].get(k, 0)
            if d:
                counters[k] = int(d) if float(d).is_integer() else d
        gauges = {k: v for k, v in after["gauges"].items()
                  if before["gauges"].get(k) != v}
        return {"counters": counters, "gauges": gauges}


class _Scope:
    def __init__(self, registry: MetricsRegistry, before: dict):
        self._registry = registry
        self._before = before

    def delta(self) -> dict:
        return self._registry.delta_since(self._before)


#: the process-wide default registry (what the built-in instrumentation
#: increments; tests construct their own for isolation)
REGISTRY = MetricsRegistry()


def merge_obs(snapshots: list[dict]) -> dict:
    """Fold several per-run ``meta["obs"]`` payloads into one (what
    ``Runner.run_many`` stores on the merged result): counters sum, gauges
    take the max (they are high-water marks), and the ``runner`` cumulative
    block — when present — comes from the last snapshot (it already spans
    the earlier runs of the same Runner)."""
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    runner: dict | None = None
    for s in snapshots:
        for k, v in (s.get("counters") or {}).items():
            counters[k] = counters.get(k, 0) + v
        for k, v in (s.get("gauges") or {}).items():
            if k not in gauges or v > gauges[k]:
                gauges[k] = v
        runner = s.get("runner", runner)
    out = {"counters": counters, "gauges": gauges}
    if runner is not None:
        out["runner"] = runner
    return out
