"""repro.obs — observability for the benchmark subsystem.

The paper's whole argument is that a throughput number only means something
with its measurement conditions attached.  This package attaches them three
ways, each consumable on its own:

* ``trace``   — a zero-dependency span tracer (stdlib only).  Off by
  default; enabled via ``--trace`` on the CLI, ``REPRO_TRACE=1`` in the
  environment, or ``trace.configure(enabled=True)`` in code.  The Runner,
  the backends, the distributed launcher, and the adaptive characterizer
  are instrumented; spans export as JSON-lines or Chrome trace-event JSON
  (loadable in Perfetto / ``chrome://tracing``).
* ``metrics`` — a counter/gauge registry (cache hits/misses, buffers
  built/released, peak resident working-set bytes, audit waivers,
  straggler kills, adaptive rounds).  Always on (increments are dict ops
  outside the timed path); every ``Runner.run`` snapshots its delta into
  ``BenchResult.meta["obs"]`` (result schema v6).
* ``ledger``  — a persistent on-disk run history (``BENCH_history/``):
  every CLI ``run`` / ``characterize`` / ``istream`` / ``latency``
  invocation appends one compact record (spec digest, machine identity,
  per-mix bandwidth curves with noise statistics, latency knees, trace
  path).  ``python -m repro.bench history`` lists it and ``python -m
  repro.bench diff`` gates regressions with the same noise-aware
  two-sample test ``characterize.detect`` uses for plateau merging.

Import discipline: ``trace`` and ``metrics`` import ONLY the stdlib (they
are safe from any module, including ``core.timing``); ``ledger`` defers its
``repro.bench`` / ``repro.characterize`` imports into function bodies.
"""
from repro.obs import ledger, metrics, trace
from repro.obs.ledger import append_record, diff_records, read_ledger
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import Tracer, configure, get_tracer

__all__ = [
    "trace", "metrics", "ledger",
    "Tracer", "configure", "get_tracer",
    "REGISTRY", "MetricsRegistry",
    "append_record", "read_ledger", "diff_records",
]
