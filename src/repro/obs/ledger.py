"""Persistent run ledger — every benchmark invocation leaves a record.

Layout (``BENCH_history/`` by default; override with ``--history-root`` or
``REPRO_BENCH_HISTORY``):

    BENCH_history/
        VERSION         ledger format version (this module refuses newer)
        ledger.jsonl    one compact JSON record per line, append-only

A record is NOT the full result (those go wherever ``--out`` points): it
is the diffable summary — spec digest, machine identity, per-cell
bandwidth curves *with noise statistics* (mean GB/s, sample count, and the
log-space sigma from the per-rep samples result schema v6 retains), the
loaded-latency knees when present, the obs counters, and the trace path.
Records are the write path of the fleet machine-model store the ROADMAP
names: one ledger per node, diffed against a stored baseline.

``diff_records`` is the regression gate: per curve cell, a two-sample test
on log-bandwidth using the SAME noise-aware threshold
``characterize.detect.significant_step`` applies when merging plateau
segments — ``max(log(1+tolerance), z·σ·√(1/n₁+1/n₂))``.  A significant
*drop* is a regression (CLI ``diff`` exits 2); a significant rise is
reported as an improvement; anything inside the threshold is noise.  A
record diffed against itself is identical by construction (exit 0).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import time
from dataclasses import dataclass, field
from pathlib import Path

LEDGER_VERSION = 1
LEDGER_ENV = "REPRO_BENCH_HISTORY"
DEFAULT_ROOT = "BENCH_history"

#: curve cells are keyed by every knob that changes what the number means
CELL_KEY = ("mix", "nbytes", "devices", "unroll", "interleave", "load")


def ledger_root(root: str | Path | None = None) -> Path:
    return Path(root or os.environ.get(LEDGER_ENV) or DEFAULT_ROOT)


def spec_digest(spec: dict) -> str:
    """Stable short digest of a spec dict (sorted-key canonical JSON)."""
    blob = json.dumps(spec, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


# ---------------------------------------------------------------------------
# record construction
# ---------------------------------------------------------------------------

def _median(sorted_vals: list) -> float:
    k = len(sorted_vals)
    mid = k // 2
    if k % 2:
        return sorted_vals[mid]
    return 0.5 * (sorted_vals[mid - 1] + sorted_vals[mid])


def _cell_stats(points: list) -> dict:
    """Mean GB/s + noise statistics for one curve cell's points.

    ``n`` counts raw timing samples (the per-rep retention of schema v6)
    and ``log_sigma`` is a MAD-robust scale of log-throughput across them
    (1.4826 * median |log t - median log t|) — since gbps = bytes/t,
    scale(log gbps) == scale(log t).  Robust matters here: a single cold
    first rep is routinely 4-7x slower on a shared host, and a plain
    sample std inflated by that outlier deadens the regression gate (the
    same reason ``characterize.detect`` sizes its plateau-merge noise with
    a MAD estimator).  Points without retained samples fall back to reps
    and the coefficient of variation (≈ sigma of the log for small
    noise)."""
    gbps = [p.gbps for p in points]
    mean = sum(gbps) / len(gbps)
    n = 0
    var_sum, var_n = 0.0, 0
    for p in points:
        samples = getattr(p, "rep_times_s", None)
        if samples:
            n += len(samples)
            logs = sorted(math.log(t) for t in samples if t > 0)
            if len(logs) > 1:
                med = _median(logs)
                mad = _median(sorted(abs(x - med) for x in logs))
                var_sum += (1.4826 * mad) ** 2
                var_n += 1
        else:
            n += p.reps
            if p.mean_s:
                var_sum += (p.std_s / p.mean_s) ** 2
                var_n += 1
    sigma = math.sqrt(var_sum / var_n) if var_n else 0.0
    cell = {"gbps": mean, "n": max(n, 1), "log_sigma": sigma}
    lats = [p.latency_ns for p in points
            if getattr(p, "latency_ns", None) is not None]
    if lats:
        cell["latency_ns"] = sum(lats) / len(lats)
    return cell


def record_from_result(res, *, cmd: str = "run", trace_path=None,
                       out_path=None, extra: dict | None = None) -> dict:
    """Compact ledger record for one BenchResult (no file IO)."""
    cells: dict[tuple, list] = {}
    for p in res.points:
        key = tuple(getattr(p, k, None) for k in CELL_KEY)
        cells.setdefault(key, []).append(p)
    curves = []
    for key in sorted(cells, key=lambda k: tuple(str(x) for x in k)):
        cell = dict(zip(CELL_KEY, key))
        cell.update(_cell_stats(cells[key]))
        curves.append(cell)
    meta = res.meta or {}
    rec = {
        "ledger_version": LEDGER_VERSION,
        "time_unix_s": time.time(),
        "cmd": cmd,
        "spec_digest": spec_digest(res.spec or {}),
        "schema_version": res.schema_version,
        "backend": (res.spec or {}).get("backend"),
        "machine": {k: res.machine.get(k)
                    for k in ("hostname", "arch", "device_platform",
                              "device_kind", "device_count", "process_count")
                    if k in (res.machine or {})},
        "mixes": list(meta.get("mixes") or []),
        "sizes": list(meta.get("sizes") or []),
        "curves": curves,
        "knees": (meta.get("loaded_latency") or {}).get("fit"),
        "obs": meta.get("obs"),
        "trace": str(trace_path) if trace_path else None,
        "out": str(out_path) if out_path else None,
    }
    if extra:
        rec.update(extra)
    return rec


# ---------------------------------------------------------------------------
# on-disk ledger
# ---------------------------------------------------------------------------

def append_record(res_or_record, *, root=None, **kw) -> tuple[Path, dict]:
    """Append one record (built from a BenchResult unless already a dict)
    to the ledger; returns (ledger path, record).  Append-only: existing
    history is never rewritten (the ``--force`` overwrite rule is about
    result files, not the ledger)."""
    rec = (res_or_record if isinstance(res_or_record, dict)
           else record_from_result(res_or_record, **kw))
    rootp = ledger_root(root)
    rootp.mkdir(parents=True, exist_ok=True)
    vfile = rootp / "VERSION"
    if vfile.exists():
        _check_version(int(vfile.read_text().strip()), vfile)
    else:
        vfile.write_text(f"{LEDGER_VERSION}\n")
    path = rootp / "ledger.jsonl"
    with path.open("a") as f:
        f.write(json.dumps(rec) + "\n")
    return path, rec


def _check_version(ver: int, where) -> None:
    if ver > LEDGER_VERSION:
        raise ValueError(f"ledger at {where} has version {ver}, newer than "
                         f"supported {LEDGER_VERSION}")


def read_ledger(root=None) -> list[dict]:
    """All records, oldest first; [] when no ledger exists yet."""
    path = ledger_root(root) / "ledger.jsonl"
    if not path.exists():
        return []
    records = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        _check_version(rec.get("ledger_version", LEDGER_VERSION), path)
        records.append(rec)
    return records


def resolve_ref(ref, root=None) -> dict:
    """A baseline reference → ledger record.

    Accepted forms: an integer index into the ledger (Python indexing:
    ``-1`` = newest, ``0`` = oldest), the string ``latest``, a path to a
    JSON file (either a saved ledger record or a full BenchResult, which
    is summarized on the fly), or a spec-digest prefix (newest match
    wins)."""
    s = str(ref)
    if s == "latest":
        s = "-1"
    try:
        idx = int(s)
    except ValueError:
        idx = None
    records = read_ledger(root)
    if idx is not None:
        if not records:
            raise ValueError(f"ledger at {ledger_root(root)} is empty; "
                             f"cannot resolve index {idx}")
        try:
            return records[idx]
        except IndexError:
            raise ValueError(f"ledger index {idx} out of range "
                             f"({len(records)} record(s))") from None
    p = Path(s)
    if p.exists():
        d = json.loads(p.read_text())
        if "ledger_version" in d:
            _check_version(d["ledger_version"], p)
            return d
        if "points" in d:       # a full BenchResult file
            from repro.bench.result import BenchResult
            return record_from_result(BenchResult.from_dict(d),
                                      cmd="file", out_path=p)
        raise ValueError(f"{p} is neither a ledger record nor a BenchResult")
    matches = [r for r in records if r.get("spec_digest", "").startswith(s)]
    if matches:
        return matches[-1]
    raise ValueError(f"cannot resolve ledger ref {ref!r}: not an index, an "
                     f"existing file, or a digest prefix of the "
                     f"{len(records)} record(s) in {ledger_root(root)}")


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------

@dataclass
class DiffReport:
    baseline: dict
    current: dict
    rows: list[dict] = field(default_factory=list)
    missing: list[dict] = field(default_factory=list)   # cells only in base
    added: list[dict] = field(default_factory=list)     # cells only in cur
    z: float = 3.0
    tolerance: float = 0.05

    @property
    def regressions(self) -> list[dict]:
        return [r for r in self.rows if r["verdict"] == "regression"]

    @property
    def improvements(self) -> list[dict]:
        return [r for r in self.rows if r["verdict"] == "improvement"]

    @property
    def identical(self) -> bool:
        return (not self.missing and not self.added
                and all(r["ratio"] == 1.0 for r in self.rows))

    def exit_code(self) -> int:
        return 2 if self.regressions else 0

    def summary(self) -> dict:
        return {"cells": len(self.rows),
                "regressions": len(self.regressions),
                "improvements": len(self.improvements),
                "missing": len(self.missing), "added": len(self.added),
                "z": self.z, "tolerance": self.tolerance}

    def table(self) -> str:
        lines = [f"{'cell':38s} {'base GB/s':>10s} {'cur GB/s':>10s} "
                 f"{'ratio':>7s}  verdict"]
        for r in self.rows:
            lines.append(f"{r['cell']:38s} {r['base_gbps']:10.2f} "
                         f"{r['cur_gbps']:10.2f} {r['ratio']:7.3f}  "
                         f"{r['verdict']}{' *' if r['significant'] else ''}")
        for m in self.missing:
            lines.append(f"{m['cell']:38s} {'(missing in current)':>30s}")
        s = self.summary()
        lines.append(f"# {s['cells']} cells: {s['regressions']} regression(s)"
                     f", {s['improvements']} improvement(s), "
                     f"{s['missing']} missing, {s['added']} added "
                     f"(z={s['z']}, tolerance={s['tolerance']:.0%})")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {"summary": self.summary(), "rows": self.rows,
                "missing": self.missing, "added": self.added,
                "baseline_digest": self.baseline.get("spec_digest"),
                "current_digest": self.current.get("spec_digest")}


def _cell_label(cell: dict) -> str:
    label = f"{cell['mix']}/{cell['nbytes']}B"
    for k in ("devices", "unroll", "interleave", "load"):
        v = cell.get(k)
        if v not in (None, 1) and (k != "load" or v != 0):
            label += f"/{k[0]}{v}"
    return label


def diff_records(baseline: dict, current: dict, *, z: float = 3.0,
                 tolerance: float = 0.05) -> DiffReport:
    """Noise-aware comparison of two records' bandwidth curves.

    Per cell present in both, a two-sample test on log-GB/s
    (``characterize.detect.significant_step`` — the plateau-merge
    threshold): the gap must clear both the physical floor
    ``log(1+tolerance)`` and ``z·σ·√(1/n₁+1/n₂)``, σ being the larger of
    the two cells' stored log-sigmas (per-rep scatter).  Only significant
    *drops* regress; cells the baseline has but the current run lacks are
    reported as missing (coverage shrank — visible, not fatal)."""
    from repro.characterize.detect import significant_step

    def index(rec):
        return {tuple(c.get(k) for k in CELL_KEY): c
                for c in rec.get("curves", [])}

    base, cur = index(baseline), index(current)
    report = DiffReport(baseline=baseline, current=current, z=z,
                        tolerance=tolerance)
    for key in sorted(set(base) & set(cur),
                      key=lambda k: tuple(str(x) for x in k)):
        b, c = base[key], cur[key]
        if b["gbps"] <= 0 or c["gbps"] <= 0:
            ratio = float("nan") if b["gbps"] <= 0 else 0.0
            sig, verdict = True, ("regression" if c["gbps"] <= 0 < b["gbps"]
                                  else "unknown")
        else:
            mb, mc = math.log(b["gbps"]), math.log(c["gbps"])
            sigma = max(b.get("log_sigma") or 0.0, c.get("log_sigma") or 0.0,
                        1e-3)
            sig = significant_step(mb, b.get("n", 1), mc, c.get("n", 1),
                                   sigma=sigma, z=z, min_drop=tolerance)
            ratio = c["gbps"] / b["gbps"]
            verdict = ("regression" if sig and ratio < 1.0 else
                       "improvement" if sig and ratio > 1.0 else "ok")
        report.rows.append({
            "cell": _cell_label(b), "key": list(key),
            "base_gbps": b["gbps"], "cur_gbps": c["gbps"], "ratio": ratio,
            "significant": sig, "verdict": verdict,
            "base_n": b.get("n"), "cur_n": c.get("n"),
        })
    report.missing = [{"cell": _cell_label(base[k]), "key": list(k)}
                      for k in sorted(set(base) - set(cur),
                                      key=lambda k: tuple(str(x)
                                                          for x in k))]
    report.added = [{"cell": _cell_label(cur[k]), "key": list(k)}
                    for k in sorted(set(cur) - set(base),
                                    key=lambda k: tuple(str(x) for x in k))]
    return report
