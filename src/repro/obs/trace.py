"""Zero-dependency span tracer — where does the wall-clock of a run go?

A benchmark harness that cannot show its own phase breakdown (compile vs
warmup vs timed reps vs buffer churn) invites exactly the unlabeled-number
mistakes the paper warns against.  This tracer is deliberately tiny:

* stdlib only — importable from anywhere (``core.timing`` uses it inside
  the repetition loop) without dragging jax/numpy in;
* **off by default** and cheap when off: ``Tracer.span`` returns a shared
  no-op context manager without allocating, and the hot timed path in
  ``core.timing.time_fn`` checks ``enabled`` ONCE and runs the original
  untraced loop when tracing is off (zero per-rep overhead — guarded by a
  test);
* thread-safe (one lock around the event list, a thread-local span stack
  for depth/nesting) and process-aware (every event records its OS pid;
  ``merge_process_traces`` re-stamps per-process event streams for the
  distributed gather);
* exception-balanced: a span records its close in ``__exit__`` even when
  the body raises (the event gains an ``error`` arg), so traces from
  failed runs still load.

Span taxonomy (see ``bench/README.md`` → Observability for the full map):
``runner.run`` > ``runner.plan`` / ``runner.size`` > ``case.build`` /
``buffers.build`` / ``runner.case`` > ``timing.warmup`` / ``timing.rep``;
``backend.<name>.make_case`` under the plan; ``launch.child`` and
``characterize.round`` at top level in their own processes.  Instant
events: ``cache`` (hit/miss), ``buffers.release``,
``launch.straggler_kill``, ``characterize.bisect``.

Export formats:

* ``write(path)`` / ``to_chrome()`` — Chrome trace-event JSON (an object
  with a ``traceEvents`` list of ``"X"`` complete / ``"i"`` instant
  events), loadable in Perfetto or ``chrome://tracing``;
* ``write_jsonl(path)`` — one event object per line, headed by a
  ``{"trace_format": "repro.obs/v1", ...}`` line (grep/stream friendly).

Timestamps are microseconds relative to the tracer's epoch
(``perf_counter_ns`` at construction/``clear``); the wall-clock anchor of
the epoch is kept in the metadata so separate traces can be aligned.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path

TRACE_FORMAT = "repro.obs/v1"

#: environment switch: any non-empty value enables the default tracer at
#: import time (the CLI's ``--trace`` flag does the same at parse time)
TRACE_ENV = "REPRO_TRACE"


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a single ``"X"`` complete event on exit."""
    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        stack = self._tracer._stack()
        # balance even if an inner span leaked (never happens with `with`,
        # but a trace must not corrupt on someone's manual __enter__)
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        args = dict(self.args)
        args["depth"] = self._depth
        if exc_type is not None:
            args["error"] = exc_type.__name__
        self._tracer._record({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self._tracer._us(self._t0),
            "dur": (t1 - self._t0) / 1e3,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args,
        })
        return False        # never swallow the body's exception


class Tracer:
    """Collects span/instant events; thread-safe; one per process."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._tls = threading.local()
        self._epoch_ns = time.perf_counter_ns()
        self._epoch_unix = time.time()

    # -- internals ----------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _us(self, t_ns: int) -> float:
        return (t_ns - self._epoch_ns) / 1e3

    def _record(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- recording API ------------------------------------------------------
    def span(self, name: str, cat: str = "bench", **args):
        """Context manager timing a phase; no-op (and allocation-free)
        while disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def event(self, name: str, cat: str = "bench", **args) -> None:
        """Instant event (Chrome ``"i"``, thread scope)."""
        if not self.enabled:
            return
        args = dict(args)
        args["depth"] = len(self._stack())
        self._record({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._us(time.perf_counter_ns()),
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args,
        })

    # -- inspection / lifecycle --------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._events]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
        self._epoch_ns = time.perf_counter_ns()
        self._epoch_unix = time.time()

    def replace_events(self, events: list[dict]) -> None:
        """Install an externally merged event list (the distributed gather
        replaces each process's local view with the global merge)."""
        with self._lock:
            self._events = [dict(e) for e in events]

    def metadata(self) -> dict:
        return {"trace_format": TRACE_FORMAT,
                "epoch_unix_s": self._epoch_unix,
                "pid": os.getpid()}

    # -- export -------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "metadata": self.metadata()}

    def write(self, path: str | Path) -> Path:
        """Write Chrome trace JSON (or JSON-lines when path ends .jsonl)."""
        path = Path(path)
        if path.suffix == ".jsonl":
            return self.write_jsonl(path)
        path.write_text(json.dumps(self.to_chrome(), indent=1))
        return path

    def write_jsonl(self, path: str | Path) -> Path:
        path = Path(path)
        lines = [json.dumps(self.metadata())]
        lines += [json.dumps(e) for e in self.events()]
        path.write_text("\n".join(lines) + "\n")
        return path


# ---------------------------------------------------------------------------
# the default (per-process) tracer
# ---------------------------------------------------------------------------

_TRACER = Tracer(enabled=bool(os.environ.get(TRACE_ENV)))


def get_tracer() -> Tracer:
    return _TRACER


def configure(enabled: bool | None = None, clear: bool = False) -> Tracer:
    """Runtime switch for the default tracer (what ``--trace`` flips)."""
    if clear:
        _TRACER.clear()
    if enabled is not None:
        _TRACER.enabled = enabled
    return _TRACER


def span(name: str, cat: str = "bench", **args):
    """Module-level convenience on the default tracer."""
    return _TRACER.span(name, cat=cat, **args)


def event(name: str, cat: str = "bench", **args) -> None:
    _TRACER.event(name, cat=cat, **args)


# ---------------------------------------------------------------------------
# multi-process merge + trace analysis helpers
# ---------------------------------------------------------------------------

def merge_process_traces(per_process: list[list[dict]]) -> list[dict]:
    """Merge per-process event streams into one trace.

    ``per_process[i]`` is process i's event list; every event is re-stamped
    with ``pid = i`` (the *mesh process index*, stable and meaningful,
    unlike the OS pid which collides across hosts) and the merge is
    stable-sorted by ``(ts, pid)`` so interleaving is deterministic given
    the timestamps.  Each process's clock is its own epoch — spans stay
    internally consistent per pid; cross-pid ordering is best-effort, which
    is all a straggler investigation needs.
    """
    merged: list[dict] = []
    for i, events in enumerate(per_process):
        for e in events:
            e = dict(e)
            e["pid"] = i
            merged.append(e)
    merged.sort(key=lambda e: (e.get("ts", 0.0), e.get("pid", 0)))
    return merged


def validate_chrome(doc: dict) -> list[str]:
    """Structural checks on a Chrome trace-event document; returns a list
    of problems (empty = valid).  This is the schema the obs CI gate and
    the trace tests assert — Perfetto is lenient, the gate is not."""
    problems = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(evs):
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                problems.append(f"event {i} missing {k!r}: {e}")
                break
        else:
            if e["ph"] not in ("X", "i", "M", "C"):
                problems.append(f"event {i} has unknown phase {e['ph']!r}")
            if e["ph"] == "X" and not (isinstance(e.get("dur"), (int, float))
                                       and e["dur"] >= 0):
                problems.append(f"event {i} ('{e['name']}') bad dur: "
                                f"{e.get('dur')!r}")
    return problems


def span_tree(events: list[dict]) -> dict:
    """Group complete-span events into per-(pid, tid) lists sorted by start
    time — nesting is recoverable from interval containment + ``depth``."""
    by_track: dict[tuple, list[dict]] = {}
    for e in events:
        if e.get("ph") == "X":
            by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    for track in by_track.values():
        track.sort(key=lambda e: e["ts"])
    return by_track


def span_coverage(events: list[dict], root: str = "runner.run") -> float:
    """Fraction of the (longest) ``root`` span's duration covered by its
    direct children — the ≥95% wall-clock accounting check.  Returns 0.0
    when no root span is present."""
    roots = [e for e in events if e.get("ph") == "X" and e["name"] == root]
    if not roots:
        return 0.0
    r = max(roots, key=lambda e: e["dur"])
    if r["dur"] <= 0:
        return 0.0
    depth = r.get("args", {}).get("depth", 0)
    lo, hi = r["ts"], r["ts"] + r["dur"]
    covered = 0.0
    for e in events:
        if (e.get("ph") == "X" and e is not r
                and e.get("pid") == r["pid"] and e.get("tid") == r["tid"]
                and e.get("args", {}).get("depth") == depth + 1
                and e["ts"] >= lo - 1e-6 and e["ts"] + e["dur"] <= hi + 1e-6):
            covered += e["dur"]
    return covered / r["dur"]
