"""Analytic per-device HBM traffic model.

XLA's cost-model "bytes accessed" sums operand bytes of every HLO op with no
fusion — flash-attention score blocks, which never leave VMEM on TPU, get
counted as HBM round trips, overstating the memory term by orders of
magnitude.  The §Roofline memory term therefore uses this analytic model
(weights + optimizer state + residual/projection activations + caches + logit
chunks, all at their *sharded* per-device sizes); the raw cost-model number is
reported alongside as ``hbm_bytes_upper``.
"""
from __future__ import annotations

from repro.configs import ArchConfig, ShapeConfig, param_count
from repro.models.common import vocab_padded


def analytic_bytes(cfg: ArchConfig, shape: ShapeConfig, n_devices: int,
                   tp: int, dp: int, cache_bytes_per_elem: int = 2,
                   train_passes: int = 3) -> float:
    """Per-device HBM bytes for one step (train: fwd+bwd+recompute+opt)."""
    P_total, P_active = param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    Vp = vocab_padded(cfg)
    L = max(cfg.n_layers, 1)

    # per-device activation shard factor: batch over dp, seq over tp
    act_shard = max(dp, 1) * max(tp, 1)

    def act_bytes_per_layer():
        """bf16 tensors that cross HBM per layer (block inputs/outputs +
        projection results); attention/FFN inner temps stay on-chip."""
        hd = cfg.resolved_head_dim
        width = 2 * D                       # residual in + out
        if cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid") and cfg.n_heads:
            width += (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * hd  # qkv+o
        if cfg.family in ("ssm", "hybrid") and cfg.ssm:
            d_in = cfg.ssm.expand * D
            width += 3 * d_in               # z, x, y streams
        if cfg.moe:
            width += 2 * cfg.moe.top_k * D  # dispatch/combine gathers
        elif cfg.d_ff:
            width += 3 * cfg.d_ff           # gate/up/down intermediates
        return B * S * width * 2 / act_shard

    if shape.kind == "train":
        # weights: fwd + bwd (+ remat recompute) reads (bf16, tp-sharded) +
        # optimizer p/m/v rw (fully sharded)
        w = train_passes * P_total * 2 / max(tp, 1)
        opt = 28.0 * P_total / n_devices
        acts = train_passes * L * act_bytes_per_layer()
        logits = 3 * B * S * Vp * 4 / act_shard       # xent chunks f32 (r+w+bwd)
        return w + opt + acts + logits
    if shape.kind == "prefill":
        w = P_total * 2 / max(tp, 1)
        acts = L * act_bytes_per_layer()
        cache_w = _cache_bytes(cfg, B, S) / n_devices
        return w + acts + cache_w
    # decode: read all (active) params + read-modify-write cache + logits
    w = P_active * 2 / max(tp, 1)
    scale = cache_bytes_per_elem / 2.0                # fp8 halves KV bytes
    cache = 2 * scale * _cache_bytes(cfg, B, S) / n_devices
    logits = B * 1 * Vp * 4 / n_devices
    return w + cache + logits


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    """Global cache bytes (bf16 KV / f32 SSM state)."""
    hd = cfg.resolved_head_dim
    if cfg.mla:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        return B * S * cfg.n_layers * per_tok * 2.0
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        return cfg.n_layers * B * (H * s.head_dim * s.d_state * 4.0
                                   + 3 * s.conv_width * d_in * 2.0)
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        ssm = cfg.n_layers * B * (H * s.head_dim * s.d_state * 4.0
                                  + 3 * s.conv_width * d_in * 2.0)
        n_sites = cfg.n_layers // cfg.attn_every
        kv = n_sites * B * S * 2 * cfg.n_kv_heads * hd * 2.0
        return ssm + kv
    if cfg.family == "encdec":
        self_kv = cfg.n_layers * B * S * 2 * cfg.n_kv_heads * hd * 2.0
        cross = cfg.n_layers * B * cfg.n_audio_ctx * 2 * cfg.n_kv_heads * hd * 2.0
        return self_kv + cross
    return cfg.n_layers * B * S * 2 * cfg.n_kv_heads * hd * 2.0
