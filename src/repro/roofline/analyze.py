"""Three-term roofline from compiled dry-run artifacts.

  compute    = HLO_FLOPs            / (peak_FLOP/s)          [per device]
  memory     = HLO_bytes            / (HBM_bw)               [per device]
  collective = sum over collective ops of ring-model time    [per device]

cost_analysis() is per-device after SPMD partitioning (verified empirically).
Collective bytes are NOT in cost_analysis — we parse the compiled HLO text and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, attributing each to the mesh axis it runs over via its
replica_groups size.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[256,1024]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    bytes: int
    group_size: int


@dataclass
class RooflineTerms:
    flops: float                   # per-device HLO flops
    hbm_bytes: float               # per-device HLO bytes accessed
    collectives: list[CollectiveOp] = field(default_factory=list)
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def collective_bytes(self) -> int:
        return sum(c.bytes for c in self.collectives)

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        """Ring model per op: all-reduce 2(n-1)/n, ag/rs (n-1)/n, a2a (n-1)/n,
        permute 1 hop.  bytes are the (per-device) operand bytes."""
        t = 0.0
        for c in self.collectives:
            n = max(c.group_size, 1)
            if n == 1:
                continue
            if c.kind == "all-reduce":
                f = 2 * (n - 1) / n
            elif c.kind in ("all-gather", "reduce-scatter", "all-to-all"):
                f = (n - 1) / n
            else:  # collective-permute: single hop
                f = 1.0
            t += f * c.bytes / self.ici_bw
        return t

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "n_collectives": len(self.collectives),
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Sum operand sizes of every collective in compiled HLO text."""
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:   # async pair: count the -start only
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        if kind == "all-gather":
            # operand (input) bytes are output/group_size; ring cost uses the
            # full gathered bytes — use output shape (what the wire carries).
            pass
        gsize = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            first = gm.group(1).split("}")[0].split("{")[-1]
            gsize = len([x for x in first.split(",") if x.strip() != ""])
        else:
            gm2 = _GROUPS_V2_RE.search(line)
            if gm2:
                gsize = int(gm2.group(2))
        ops.append(CollectiveOp(kind=kind, bytes=nbytes, group_size=gsize))
    return ops


def machine_constants(machine) -> dict:
    """Roofline constants from any machine-model flavor, for RooflineTerms.

    Accepts a ``characterize.FittedMachineModel`` (measured: ``peak_flops``
    / ``hbm_bw`` properties), a ``core.machine_model.HardwareSpec``
    (documented: outermost level ``read_bw`` + ``link_bw``), or a registry
    name string (``core.machine_model.get_spec``).  Constants the model
    does not know (None = undocumented/unmeasured) keep the v5e defaults —
    callers can see which were overridden in the returned dict.
    """
    if machine is None:
        return {}
    if isinstance(machine, str):
        from repro.core.machine_model import get_spec
        machine = get_spec(machine)
    out = {}
    peak = getattr(machine, "peak_flops", None)
    if peak:
        out["peak_flops"] = float(peak)
    hbm = getattr(machine, "hbm_bw", None)      # FittedMachineModel (measured)
    if hbm is None:                             # HardwareSpec (documented)
        levels = getattr(machine, "levels", ())
        if levels:
            hbm = getattr(levels[-1], "read_bw", None)
    if hbm:
        out["hbm_bw"] = float(hbm)
    ici = getattr(machine, "link_bw", None)
    if ici:
        out["ici_bw"] = float(ici)
    return out


def analyze(compiled, model_flops: float | None = None,
            machine=None) -> dict:
    """Full §Roofline record for one compiled (arch x shape x mesh) cell.

    ``machine`` (optional) replaces the static v5e constants with a machine
    model's — pass the ``FittedMachineModel`` that ``repro.characterize``
    measured on this very machine, a documented ``HardwareSpec``, or a spec
    registry name; see ``machine_constants``."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per computation
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    mc = machine_constants(machine)
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm, collectives=colls,
                          **mc)
    mem = compiled.memory_analysis()
    out = {
        **terms.summary(),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_device_bytes": int(mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes),
        "collective_breakdown": _breakdown(colls),
    }
    if model_flops is not None:
        out["model_flops"] = model_flops
        out["useful_flop_ratio"] = model_flops / flops if flops else 0.0
    if machine is not None:
        out["machine_model"] = getattr(machine, "name", str(machine))
        out["machine_constants"] = mc
    return out


def _breakdown(colls: list[CollectiveOp]) -> dict:
    agg: dict[str, dict] = {}
    for c in colls:
        a = agg.setdefault(c.kind, {"count": 0, "bytes": 0})
        a["count"] += 1
        a["bytes"] += c.bytes
    return agg
