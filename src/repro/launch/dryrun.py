"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the production
meshes with 512 placeholder host devices, and extract roofline terms.

MUST be executed as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line below runs before any other import so the forced device count
takes effect at first jax init.  Never import this module from tests.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch, list_archs, param_count  # noqa: E402
from repro.distributed.sharding import ShardCtx                      # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402
from repro.models.common import abstract_params, logical_axes        # noqa: E402
from repro.models.registry import build, cache_abstract, input_abstract  # noqa: E402
from repro.models.variant import VARIANTS, Variant                   # noqa: E402
from repro.roofline.analyze import analyze                           # noqa: E402
from repro.train.step import (make_decode_step, make_prefill_step,   # noqa: E402
                              make_train_step)

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"
HBM_PER_DEVICE = 16 * 2**30  # v5e


def _replicated(mesh, sds):
    return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                sharding=NamedSharding(mesh, P()))


def lower_cell(arch: str, shape_name: str, multi_pod: bool, variant_name: str,
               compile_only: bool = False):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cfg.supports_shape(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant_name, "status": "skipped", "reason": reason}

    variant = VARIANTS[variant_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh)
    from repro.models.variant import apply_rules
    apply_rules(ctx, variant)
    model = build(cfg)

    specs = model.param_specs()
    p_abs = ctx.tree_abstract(abstract_params(specs), logical_axes(specs))
    if shape.kind in ("prefill", "decode"):
        # serving holds bf16 weights (production standard; f32 is a train-only
        # luxury) — halves the serving footprint of the 200B+ archs.
        p_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16,
                                           sharding=s.sharding), p_abs)
    batch_abs, batch_axes = input_abstract(cfg, shape)
    b_abs = ctx.tree_abstract(batch_abs, batch_axes)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            step_fn = make_train_step(cfg, ctx, variant=variant)
            mdt = jnp.dtype(variant.adam_dtype)
            mom = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, mdt, sharding=s.sharding),
                p_abs)
            o_abs = {"mu": mom, "nu": mom,
                     "step": _replicated(mesh, jax.ShapeDtypeStruct((), jnp.int32))}
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                p_abs, o_abs, b_abs)
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(cfg, ctx, variant=variant)
            lowered = jax.jit(step_fn).lower(p_abs, b_abs)
        else:  # decode
            dp = ctx.axis_size(*ctx.dp_axes)
            seq_shard = (shape.global_batch % dp) != 0
            step_fn = make_decode_step(cfg, ctx, variant=variant,
                                       seq_shard_decode=seq_shard)
            c_abs_raw, c_axes = cache_abstract(cfg, shape.global_batch,
                                               shape.seq_len)
            c_abs = ctx.tree_abstract(c_abs_raw, c_axes)
            cache_dt = jnp.dtype(variant.kv_cache_dtype)
            if cache_dt != jnp.bfloat16:
                c_abs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, cache_dt,
                                                   sharding=s.sharding)
                    if s.dtype == jnp.bfloat16 else s, c_abs)
            pos = _replicated(mesh, jax.ShapeDtypeStruct((), jnp.int32))
            lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(
                p_abs, c_abs, b_abs, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    total, active = param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops_global = mult * active * tokens
    n_dev = mesh.devices.size
    rec = analyze(compiled, model_flops=model_flops_global / n_dev)
    rec.update({
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant_name, "status": "ok",
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "n_devices": int(n_dev),
        "params_total": total, "params_active": active,
        "tokens_per_step": tokens,
        "fits_hbm": rec_fits(rec),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "sharding_fallbacks": sorted(set(ctx.fallbacks)),
    })
    return rec


def rec_fits(rec) -> bool:
    return rec["peak_device_bytes"] <= HBM_PER_DEVICE


def cell_path(arch, shape_name, multi_pod, variant) -> Path:
    mesh_tag = "pod2" if multi_pod else "pod1"
    return ART / f"{arch}__{shape_name}__{mesh_tag}__{variant}.json"


def run_cell(arch, shape_name, multi_pod, variant, force=False) -> dict:
    out = cell_path(arch, shape_name, multi_pod, variant)
    if out.exists() and not force:
        return json.loads(out.read_text())
    try:
        rec = lower_cell(arch, shape_name, multi_pod, variant)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "variant": variant, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mp, args.variant, force=args.force)
                status = rec.get("status")
                tag = f"{arch} x {shape} x {'pod2' if mp else 'pod1'} x {args.variant}"
                if status == "ok":
                    print(f"[ok]   {tag}: dominant={rec['dominant']} "
                          f"t=({rec['t_compute_s']:.4f},{rec['t_memory_s']:.4f},"
                          f"{rec['t_collective_s']:.4f})s "
                          f"peak={rec['peak_device_bytes']/2**30:.2f}GiB "
                          f"fits={rec['fits_hbm']} ({time.time()-t0:.0f}s)")
                elif status == "skipped":
                    print(f"[skip] {tag}: {rec['reason']}")
                else:
                    print(f"[ERR]  {tag}: {rec['error']}")


if __name__ == "__main__":
    main()
