"""Production mesh factory.

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic scaling / tests).  Axis names must come from
    {pod, data, model} so the sharding rules apply unchanged."""
    assert set(axes) <= {"pod", "data", "model"}, axes
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
