"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --batch 8 --seq 256 --steps 100

Production posture: on a real multi-host slice the same entry point runs under
``jax.distributed.initialize()`` (one process per host); mesh axes come from
--mesh.  On this container it runs single-process (optionally with forced host
devices via --force-devices, set before jax init).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1,1,1",
                    help="pod,data,model axis sizes")
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    if args.force_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.force_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.models.variant import VARIANTS
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(shape, ("pod", "data", "model")[:len(shape)]
                     if len(shape) == 3 else ("data", "model"))
    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        opt=adamw.AdamWConfig(lr=args.lr, total_steps=args.steps))
    trainer = Trainer(cfg, (args.batch, args.seq), mesh, tcfg,
                      variant=VARIANTS[args.variant])
    _, _, hist = trainer.train(resume=not args.no_resume)
    if hist:
        print(f"final loss: {hist[-1]['loss']:.4f} "
              f"(from {hist[0]['loss']:.4f} @ step {hist[0]['step']})")


if __name__ == "__main__":
    main()
