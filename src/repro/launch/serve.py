"""Serving launcher: batched prefill + greedy decode with the KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.distributed.sharding import make_smoke_ctx
    from repro.models.common import init_params
    from repro.models.registry import build, init_cache, make_batch
    from repro.models.variant import BASELINE

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    ctx = make_smoke_ctx()
    model = build(cfg)
    params = init_params(model.param_specs(), jax.random.key(args.seed))
    B, P, G = args.batch, args.prompt_len, args.gen
    batch = make_batch(cfg, (B, P), jax.random.key(args.seed + 1))
    cache = init_cache(cfg, B, P + G)

    with jax.set_mesh(ctx.mesh):
        dec = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, ctx,
                                                             BASELINE))
        toks = batch["tokens"][:, :1]
        generated = []
        t_first = t0 = time.perf_counter()
        c = cache
        for i in range(P + G - 1):
            logits, c = dec(params, c, toks, jnp.int32(i))
            jax.block_until_ready(logits)
            if i == 0:
                t_first = time.perf_counter()
            if i < P - 1:
                toks = batch["tokens"][:, i + 1:i + 2]   # teacher-forced prompt
            else:
                toks = jnp.argmax(logits[:, :, :cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)
                generated.append(int(toks[0, 0]))
        dt = time.perf_counter() - t_first
        n_steps = P + G - 2
        print(f"arch={cfg.name} batch={B} prompt={P} gen={G}")
        print(f"sample continuation (seq 0): {generated}")
        print(f"decode throughput: {B * n_steps / dt:.1f} tok/s "
              f"({dt / n_steps * 1e3:.1f} ms/step @ batch {B})")


if __name__ == "__main__":
    main()
