"""Per-layer roofline cost probes.

XLA-CPU's ``cost_analysis`` counts a ``while`` body ONCE regardless of trip
count (verified: a scan of 10 matmuls reports 1 matmul of flops) — so the
rolled dry-run program under-reports flops/bytes/collectives by ~n_layers.
This module lowers each *part* of a step once, with inner scans unrolled
(attention q/kv blocks, xent chunks), and composes totals analytically:

    train:   total = L x (grad(layer) + fwd(layer))   [+fwd = remat recompute]
                   + grad(head) + fwd(head) + optimizer(analytic)
    prefill: total = L x fwd(layer) + fwd(head)
    decode:  total = L x fwd(layer_decode) + fwd(head)

The rolled lowering (launch/dryrun.py) remains the compile + memory-fit proof;
records produced here carry ``"source": "probe"`` and feed §Roofline/§Perf.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from dataclasses import replace  # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_arch, list_archs, param_count  # noqa: E402
from repro.distributed.sharding import ShardCtx                      # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402
from repro.models import attention as attn_mod                       # noqa: E402
from repro.models.common import (abstract_params, apply_norm,        # noqa: E402
                                 chunked_softmax_xent, embed_specs,
                                 embed_tokens, lm_logits, logical_axes,
                                 norm_specs)
from repro.models.registry import build                              # noqa: E402
from repro.models.variant import VARIANTS, Variant                   # noqa: E402
from repro.roofline.analyze import (CollectiveOp, RooflineTerms,     # noqa: E402
                                    parse_collectives)

ART = Path(__file__).resolve().parents[3] / "artifacts" / "probe"


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def _cost(fn, args_abs, mesh):
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn).lower(*args_abs).compile()
    c = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    return {"flops": float(c.get("flops", 0.0)),
            "bytes": float(c.get("bytes accessed", 0.0)),
            "colls": colls}


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "colls": []}


def _add(a, b, mult=1.0):
    return {"flops": a["flops"] + mult * b["flops"],
            "bytes": a["bytes"] + mult * b["bytes"],
            "colls": a["colls"] + [CollectiveOp(c.kind,
                                                int(c.bytes * mult),
                                                c.group_size)
                                   for c in b["colls"]]}


def _scalarize(tree):
    return sum(jnp.sum(x.astype(jnp.float32)) for x in jax.tree.leaves(tree))


def _train_part(fwd_fn, args_abs, mesh, remat: str = "full",
                cast_params: bool = False):
    """Training-visit cost of one part.

    remat=full: grad(part) + fwd(part)   (backward recomputes the forward)
    else:       grad(part)               (dots policy keeps matmul outputs)
    cast_params=True casts f32 weight args to bf16 inside the probed fn so the
    FSDP all-gathers in the lowered part carry bf16 (mirrors train_step).
    """
    def maybe_cast(args):
        if not cast_params:
            return args
        return tuple(jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (hasattr(p, "dtype") and p.dtype == jnp.float32 and p.ndim > 1)
            else p, a) for a in args)

    def loss(*args):
        return _scalarize(fwd_fn(*maybe_cast(args)))
    fwd = _cost(loss, args_abs, mesh)
    # differentiate only w.r.t. float-valued args (tokens/labels are int32)
    argnums = tuple(
        i for i, a in enumerate(args_abs)
        if all(jnp.issubdtype(l.dtype, jnp.inexact)
               for l in jax.tree.leaves(a)))
    grad = _cost(jax.grad(loss, argnums=argnums), args_abs, mesh)
    return _add(fwd, grad) if remat == "full" else grad


def _abs(ctx, shape, axes, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(tuple(shape), dtype,
                                sharding=ctx.sharding(tuple(shape), tuple(axes)))


def _layer_abstract(ctx, model_specs_subtree):
    return ctx.tree_abstract(abstract_params(model_specs_subtree),
                             logical_axes(model_specs_subtree))


def _unstack(stacked_specs_tree):
    """Strip the leading (layers/sites,) dim off a stacked spec tree."""
    from repro.models.common import ParamSpec, spec_map
    return spec_map(lambda s: ParamSpec(s.shape[1:], s.axes[1:], s.init,
                                        s.scale, s.dtype), stacked_specs_tree)


# ---------------------------------------------------------------------------
# per-family parts
# ---------------------------------------------------------------------------

def probe_parts(cfg, shape, ctx, variant):
    """Returns list of (name, multiplier, cost_dict)."""
    mesh = ctx.mesh
    model = build(cfg)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    pv0 = variant
    pv = replace(variant, unroll=True, remat="none",
                 kv_block=max(variant.kv_block,
                              2048 if S >= 32768 else variant.kv_block))
    cache_dt = jnp.dtype(variant.kv_cache_dtype)

    def cache_cast(abs_tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, cache_dt,
                                           sharding=s.sharding)
            if s.dtype == jnp.bfloat16 else s, abs_tree)
    kind = shape.kind
    parts = []
    positions = None  # models default to arange

    x_abs = _abs(ctx, (B, S, D), ("batch", "act_seq", None))
    tok_abs = _abs(ctx, (B, S), ("batch", "seq"), jnp.int32)

    def head_fn(emb_p, lnf_p, h, tokens, labels):
        x0 = embed_tokens(emb_p, tokens)
        h = apply_norm(cfg, lnf_p, h + 0 * x0)
        return chunked_softmax_xent(cfg, emb_p, h, labels,
                                    chunk=pv.xent_chunk, unroll=True)

    emb_abs = _layer_abstract(ctx, embed_specs(cfg))
    lnf_abs = _layer_abstract(ctx, norm_specs(cfg, D))

    if cfg.family in ("dense", "vlm", "moe"):
        lp_abs = _layer_abstract(ctx, model.block_specs())
        if kind == "train":
            fn = lambda lp, x: model._block(lp, x, ctx, pv, jnp.arange(S))[0]
            parts.append(("layer", cfg.n_layers,
                          _train_part(fn, (lp_abs, x_abs), mesh, pv0.remat, pv0.cast_params)))
            parts.append(("head", 1, _train_part(
                head_fn, (emb_abs, lnf_abs, x_abs, tok_abs, tok_abs), mesh,
                pv0.remat, pv0.cast_params)))
        elif kind == "prefill":
            fn = lambda lp, x: model._block(lp, x, ctx, pv, jnp.arange(S))[0]
            parts.append(("layer", cfg.n_layers,
                          _cost(lambda lp, x: _scalarize(fn(lp, x)),
                                (lp_abs, x_abs), mesh)))
        else:  # decode
            cshapes = model.cache_shapes(B, S)
            c_abs = cache_cast({k: _abs(ctx, v[0], v[1], v[2])
                                for k, v in cshapes.items()})
            x1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            from repro.models import mla as mla_mod

            def dec_fn(lp, cache, x):
                h = apply_norm(cfg, lp["ln1"], x)
                if model.is_mla:
                    a, c2, k2 = mla_mod.mla_decode(cfg, lp["attn"], h,
                                                   cache["c"], cache["k_rope"],
                                                   jnp.int32(S - 1))
                    extra = (c2, k2)
                else:
                    a, ck, cv = attn_mod.gqa_decode(cfg, lp["attn"], h,
                                                    cache["k"], cache["v"],
                                                    jnp.int32(S - 1))
                    extra = (ck, cv)
                x = x + a
                h2 = apply_norm(cfg, lp["ln2"], x)
                if model.is_moe:
                    from repro.models import moe as moe_mod
                    y, _ = moe_mod.moe_layer(ctx, cfg, lp["moe"], h2,
                                             psum_dtype=pv.psum_dtype)
                else:
                    from repro.models.common import apply_mlp
                    y = apply_mlp(cfg, lp["mlp"], h2)
                return _scalarize(x + y) + sum(_scalarize(e[:, -1:]) for e in extra)

            parts.append(("layer_decode", cfg.n_layers,
                          _cost(dec_fn, (lp_abs, c_abs, x1), mesh)))
            h1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            parts.append(("head_decode", 1, _cost(
                lambda e, h: _scalarize(lm_logits(cfg, e, h)), (emb_abs, h1),
                mesh)))

    elif cfg.family == "ssm":
        from repro.models.ssm import ssm_block, ssm_cache_shapes, ssm_decode, ssm_specs
        lp_abs = _layer_abstract(
            ctx, {"ln": norm_specs(cfg, D), "ssm": ssm_specs(cfg)})
        if kind in ("train", "prefill"):
            fn = lambda lp, x: x + ssm_block(
                cfg, lp["ssm"], apply_norm(cfg, lp["ln"], x), ctx)
            if kind == "train":
                parts.append(("layer", cfg.n_layers,
                              _train_part(fn, (lp_abs, x_abs), mesh, pv0.remat, pv0.cast_params)))
                parts.append(("head", 1, _train_part(
                    head_fn, (emb_abs, lnf_abs, x_abs, tok_abs, tok_abs), mesh)))
            else:
                parts.append(("layer", cfg.n_layers,
                              _cost(lambda lp, x: _scalarize(fn(lp, x)),
                                    (lp_abs, x_abs), mesh)))
        else:
            cshapes = ssm_cache_shapes(cfg, B)
            c_abs = {k: _abs(ctx, v[0], v[1], v[2]) for k, v in cshapes.items()}
            x1 = _abs(ctx, (B, 1, D), ("batch", None, None))

            def dec_fn(lp, cache, x):
                y, c2 = ssm_decode(cfg, lp["ssm"],
                                   apply_norm(cfg, lp["ln"], x), cache)
                return _scalarize(x + y) + _scalarize(c2["state"][:, :1])

            parts.append(("layer_decode", cfg.n_layers,
                          _cost(dec_fn, (lp_abs, c_abs, x1), mesh)))
            h1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            parts.append(("head_decode", 1, _cost(
                lambda e, h: _scalarize(lm_logits(cfg, e, h)), (emb_abs, h1),
                mesh)))

    elif cfg.family == "hybrid":
        from repro.models.ssm import ssm_block, ssm_cache_shapes, ssm_decode, ssm_specs
        mp_abs = _layer_abstract(
            ctx, {"ln": norm_specs(cfg, D), "ssm": ssm_specs(cfg)})
        sb_abs = _layer_abstract(ctx, {
            "ln1": norm_specs(cfg, D), "attn": attn_mod.gqa_specs(cfg, D),
            "ln2": norm_specs(cfg, D),
            "mlp": __import__("repro.models.common",
                              fromlist=["mlp_specs"]).mlp_specs(cfg, D, cfg.d_ff)})
        sn_abs = _layer_abstract(ctx, norm_specs(cfg, D))
        n_sites = cfg.n_layers // cfg.attn_every
        model_h = build(cfg)

        if kind in ("train", "prefill"):
            mb = lambda lp, x: x + ssm_block(
                cfg, lp["ssm"], apply_norm(cfg, lp["ln"], x), ctx)

            def sb(sp, sn, x):
                return model_h._shared_block({"shared": sp}, sn, x, ctx, pv,
                                             jnp.arange(S))
            if kind == "train":
                parts.append(("mamba_layer", cfg.n_layers,
                              _train_part(mb, (mp_abs, x_abs), mesh, pv0.remat, pv0.cast_params)))
                parts.append(("shared_block", n_sites,
                              _train_part(sb, (sb_abs, sn_abs, x_abs), mesh, pv0.remat, pv0.cast_params)))
                parts.append(("head", 1, _train_part(
                    head_fn, (emb_abs, lnf_abs, x_abs, tok_abs, tok_abs), mesh)))
            else:
                parts.append(("mamba_layer", cfg.n_layers, _cost(
                    lambda lp, x: _scalarize(mb(lp, x)), (mp_abs, x_abs), mesh)))
                parts.append(("shared_block", n_sites, _cost(
                    lambda sp, sn, x: _scalarize(sb(sp, sn, x)),
                    (sb_abs, sn_abs, x_abs), mesh)))
        else:
            cshapes = ssm_cache_shapes(cfg, B)
            sc_abs = {k: _abs(ctx, v[0], v[1], v[2]) for k, v in cshapes.items()}
            hd = cfg.resolved_head_dim
            k_abs = cache_cast(_abs(ctx, (B, S, cfg.n_kv_heads, hd),
                                    ("batch", "kv_seq", "kv_heads", None)))
            x1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            dp = ctx.axis_size(*ctx.dp_axes)
            seq_shard = (B % dp) != 0

            def mdec(lp, cache, x):
                y, c2 = ssm_decode(cfg, lp["ssm"],
                                   apply_norm(cfg, lp["ln"], x), cache)
                return _scalarize(x + y) + _scalarize(c2["state"][:, :1])

            def sdec(sp, sn, ck, cv, x):
                h = apply_norm(cfg, sn, x)
                h1 = apply_norm(cfg, sp["ln1"], h)
                if seq_shard:
                    from repro.serve.flash_decode import seq_sharded_gqa_decode
                    a, k2, v2 = seq_sharded_gqa_decode(ctx, cfg, sp["attn"], h1,
                                                       ck, cv, jnp.int32(S - 1))
                else:
                    a, k2, v2 = attn_mod.gqa_decode(cfg, sp["attn"], h1, ck, cv,
                                                    jnp.int32(S - 1))
                return _scalarize(x + a) + _scalarize(k2[:, -1:]) + \
                    _scalarize(v2[:, -1:])

            parts.append(("mamba_decode", cfg.n_layers,
                          _cost(mdec, (mp_abs, sc_abs, x1), mesh)))
            parts.append(("shared_decode", n_sites,
                          _cost(sdec, (sb_abs, sn_abs, k_abs, k_abs, x1), mesh)))
            h1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            parts.append(("head_decode", 1, _cost(
                lambda e, h: _scalarize(lm_logits(cfg, e, h)), (emb_abs, h1),
                mesh)))

    elif cfg.family == "encdec":
        A = cfg.n_audio_ctx
        frames_abs = _abs(ctx, (B, A, D), ("batch", None, None))
        enc_abs = _layer_abstract(ctx, {
            "ln1": norm_specs(cfg, D), "attn": attn_mod.gqa_specs(cfg, D),
            "ln2": norm_specs(cfg, D),
            "mlp": __import__("repro.models.common",
                              fromlist=["mlp_specs"]).mlp_specs(cfg, D, cfg.d_ff)})
        dec_abs = _layer_abstract(ctx, {
            "ln1": norm_specs(cfg, D), "self_attn": attn_mod.gqa_specs(cfg, D),
            "ln_x": norm_specs(cfg, D), "cross_attn": attn_mod.gqa_specs(cfg, D),
            "ln2": norm_specs(cfg, D),
            "mlp": __import__("repro.models.common",
                              fromlist=["mlp_specs"]).mlp_specs(cfg, D, cfg.d_ff)})
        model_e = build(cfg)

        def enc_fn(lp, x):
            h = apply_norm(cfg, lp["ln1"], x)
            a = attn_mod.gqa_attention(cfg, lp["attn"], h, causal=False,
                                       kv_block=pv.kv_block, ctx=ctx,
                                       unroll=True)
            x = x + a
            h = apply_norm(cfg, lp["ln2"], x)
            from repro.models.common import apply_mlp
            return x + apply_mlp(cfg, lp["mlp"], h)

        def dec_fn(lp, x, enc_out):
            return model_e._dec_block(lp, x, enc_out, ctx, pv, jnp.arange(S))

        if kind == "train":
            parts.append(("enc_layer", cfg.n_encoder_layers,
                          _train_part(enc_fn, (enc_abs, frames_abs), mesh, pv0.remat, pv0.cast_params)))
            parts.append(("dec_layer", cfg.n_layers,
                          _train_part(dec_fn, (dec_abs, x_abs, frames_abs),
                                      mesh, pv0.remat, pv0.cast_params)))
            parts.append(("head", 1, _train_part(
                head_fn, (emb_abs, lnf_abs, x_abs, tok_abs, tok_abs), mesh,
                pv0.remat, pv0.cast_params)))
        elif kind == "prefill":
            parts.append(("enc_layer", cfg.n_encoder_layers, _cost(
                lambda lp, x: _scalarize(enc_fn(lp, x)), (enc_abs, frames_abs),
                mesh)))
            parts.append(("dec_layer", cfg.n_layers, _cost(
                lambda lp, x, e: _scalarize(dec_fn(lp, x, e)),
                (dec_abs, x_abs, frames_abs), mesh)))
        else:  # decode
            hd = cfg.resolved_head_dim
            kv = cfg.n_kv_heads
            k_abs = cache_cast(_abs(ctx, (B, S, kv, hd),
                                    ("batch", "kv_seq", "kv_heads", None)))
            xk_abs = _abs(ctx, (B, A, kv, hd), ("batch", None, "kv_heads",
                                                None))
            x1 = _abs(ctx, (B, 1, D), ("batch", None, None))

            def ddec(lp, ck, cv, xk, xv, x):
                h = apply_norm(cfg, lp["ln1"], x)
                a, k2, v2 = attn_mod.gqa_decode(cfg, lp["self_attn"], h, ck, cv,
                                                jnp.int32(S - 1))
                x = x + a
                h = apply_norm(cfg, lp["ln_x"], x)
                q, _, _ = attn_mod.gqa_project_qkv(cfg, lp["cross_attn"], h,
                                                   jnp.zeros((B, 1), jnp.int32),
                                                   None)
                o = attn_mod.chunked_attention(q, xk, xv, causal=False,
                                               kv_block=1024, unroll=True)
                from repro.models.common import apply_mlp, cast_compute
                x = x + jnp.einsum("bshk,hkd->bsd", o,
                                   cast_compute(lp["cross_attn"]["wo"])
                                   ).astype(x.dtype)
                h = apply_norm(cfg, lp["ln2"], x)
                x = x + apply_mlp(cfg, lp["mlp"], h)
                return _scalarize(x) + _scalarize(k2[:, -1:]) + \
                    _scalarize(v2[:, -1:])

            parts.append(("dec_layer_decode", cfg.n_layers,
                          _cost(ddec, (dec_abs, k_abs, k_abs, xk_abs, xk_abs,
                                       x1), mesh)))
            h1 = _abs(ctx, (B, 1, D), ("batch", None, None))
            parts.append(("head_decode", 1, _cost(
                lambda e, h: _scalarize(lm_logits(cfg, e, h)), (emb_abs, h1),
                mesh)))
    else:
        raise ValueError(cfg.family)

    # optimizer part (train only): elementwise AdamW, fully sharded => analytic
    if kind == "train":
        total_p, _ = param_count(cfg)
        p_local = total_p / ctx.mesh.devices.size
        parts.append(("optimizer", 1, {"flops": 15.0 * p_local,
                                       "bytes": 28.0 * p_local, "colls": []}))
    return parts


# ---------------------------------------------------------------------------
# composition + CLI
# ---------------------------------------------------------------------------

def probe_cell(arch: str, shape_name: str, multi_pod: bool, variant_name: str):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cfg.supports_shape(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant_name, "status": "skipped", "reason": reason,
                "source": "probe"}
    variant = VARIANTS[variant_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh)
    from repro.models.variant import apply_rules
    apply_rules(ctx, variant)
    t0 = time.time()
    parts = probe_parts(cfg, shape, ctx, variant)
    total = _zero()
    part_summary = {}
    for name, mult, cost in parts:
        total = _add(total, cost, mult)
        part_summary[name] = {"mult": mult, "flops": cost["flops"],
                              "bytes": cost["bytes"],
                              "coll_bytes": sum(c.bytes for c in cost["colls"])}
    from repro.roofline.model_bytes import analytic_bytes
    hbm_model = analytic_bytes(cfg, shape, ctx.mesh.devices.size,
                               tp=ctx.axis_size("model"),
                               dp=ctx.axis_size(*ctx.dp_axes),
                               cache_bytes_per_elem=jnp.dtype(
                                   variant.kv_cache_dtype).itemsize,
                               train_passes=3 if variant.remat == "full" else 2)
    terms = RooflineTerms(flops=total["flops"], hbm_bytes=hbm_model,
                          collectives=total["colls"])
    totals, active = param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult_f = 6 if shape.kind == "train" else 2
    model_flops = mult_f * active * tokens / mesh.devices.size
    rec = {
        **terms.summary(),
        "hbm_bytes_upper": total["bytes"],   # no-fusion cost-model bound
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant_name, "status": "ok", "source": "probe",
        "model_flops": model_flops,
        "useful_flop_ratio": model_flops / total["flops"] if total["flops"] else 0,
        "parts": part_summary,
        "probe_s": round(time.time() - t0, 1),
    }
    return rec


def cell_path(arch, shape_name, multi_pod, variant) -> Path:
    mesh_tag = "pod2" if multi_pod else "pod1"
    return ART / f"{arch}__{shape_name}__{mesh_tag}__{variant}.json"


def run_cell(arch, shape_name, multi_pod, variant, force=False):
    out = cell_path(arch, shape_name, multi_pod, variant)
    if out.exists() and not force:
        return json.loads(out.read_text())
    try:
        rec = probe_cell(arch, shape_name, multi_pod, variant)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "variant": variant, "status": "error", "source": "probe",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = list_archs() if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mp, args.variant, force=args.force)
                tag = f"{arch} x {shape} x {'pod2' if mp else 'pod1'} x {args.variant}"
                if rec["status"] == "ok":
                    print(f"[ok]   {tag}: dom={rec['dominant']} "
                          f"t=({rec['t_compute_s']:.4f},{rec['t_memory_s']:.4f},"
                          f"{rec['t_collective_s']:.4f})s "
                          f"useful={rec['useful_flop_ratio']:.2f} "
                          f"({time.time()-t0:.0f}s)")
                elif rec["status"] == "skipped":
                    print(f"[skip] {tag}")
                else:
                    print(f"[ERR]  {tag}: {rec['error'][:160]}")


if __name__ == "__main__":
    main()
