"""Oracle for the flash-attention kernel: plain softmax attention in f32."""
from __future__ import annotations

import jax.numpy as jnp


def reference(q, k, v, *, causal: bool = True):
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qf, kf) / (D ** 0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", w, vf)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, -1).astype(q.dtype)
