"""Causal GQA flash attention — Pallas TPU kernel (forward).

Grid (batch*kv_head, q_blocks, kv_blocks); the kv axis is innermost so the
online-softmax state (m, l, acc) lives in VMEM scratch across kv steps.  Causal
block skipping is structural: the kv loop is bounded per q block through
``pl.when`` on fully-masked blocks (the blocks the XLA 'masked' path wastes
FLOPs on — EXPERIMENTS.md §Perf quantifies that gap).

Forward-only by design: training runs the XLA path (whose backward is the
checkpointed flash scan); this kernel is the serving/prefill hot spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(G: int, scale: float, causal: bool,
                 q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    qb = q_ref.shape[2]      # q_ref: (1, G, qb, d)
    kb = k_ref.shape[1]      # k_ref: (1, kb, d)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal skip: q block qi only attends kv blocks with start <= q end
    run = (not causal) or (ki * kb <= qi * qb + qb - 1)

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32)            # (G, qb, d)
        k = k_ref[0].astype(jnp.float32)            # (kb, d)
        v = v_ref[0].astype(jnp.float32)            # (kb, dv)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (G, qb, kb), 1)
            kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (G, qb, kb), 2)
            s = jnp.where(qpos >= kpos, s, -1e30)
        m_prev = m_scr[...]                          # (G, qb)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D/Dv) -> (B, Sq, H, Dv).

    GQA: H = G * KV; the grid batches over (B * KV), each step carrying the G
    query heads of that kv head in one (G, qb, d) block.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    scale = 1.0 / (D ** 0.5)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    nq, nk = Sq // q_block, Sk // kv_block

    # layout: (B*KV, G, Sq, D) so a (G, qb, D) q block pairs with (kb, D) k block
    qr = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4).reshape(
        B * KV, G, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dv)

    kern = functools.partial(_attn_kernel, G, scale, causal)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kern,
        grid=(B * KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, q_block, D), lambda b, qi, ki: (b, 0, qi, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kv_block, Dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, q_block, Dv),
                               lambda b, qi, ki: (b, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, q_block), jnp.float32),
            pltpu.VMEM((G, q_block), jnp.float32),
            pltpu.VMEM((G, q_block, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, Sq, Dv).transpose(0, 3, 1, 2, 4).reshape(
        B, Sq, H, Dv)
