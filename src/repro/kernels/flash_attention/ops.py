"""jit'd wrapper for the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash(q, k, v, causal: bool = True, q_block: int = 256,
          kv_block: int = 256, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, q_block=q_block,
                           kv_block=kv_block, interpret=interpret)


def flops(q, k, causal: bool) -> float:
    """Useful attention flops (2*S_q*S_k*D*H*B*2 matmuls, halved if causal)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    f = 4.0 * B * H * Sq * Sk * D
    return f / 2 if causal else f
