"""Pallas TPU membench throughput kernels — the paper's measurement loop with
explicit VMEM tiling.

Knobs (mapping to the paper, DESIGN.md §2):
  mix         load_only | load_sum | copy | fma_k | mxu     (C2: LOAD/FADD/NOP)
  block_rows  rows per (block_rows, 128) VMEM tile           (C4: LD1D/LD2D/LD4D)
  streams     1 = sequential block walk (post-increment analogue);
              S > 1 = S interleaved streams via the index_map (the paper's
              four offset address pointers breaking AGU dependencies)   (C3)

``load_only`` is the mix XLA cannot express (a dead load is DCE'd): here the
block is *loaded* into VMEM by the pipeline regardless, and only one lane ever
feeds the accumulator, so the measured time is pure data movement + grid
overhead — the LD1/LD2D-only loop of §4.

The grid accumulates into a (1, 1) output revisited every step; TPU grids are
sequential per core, so the accumulation is race-free (and the revisited block
stays resident in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_body(mix: str, depth: int, blk, w=None, interleave: int = 1):
    """blk: (rows, 128) f32 tile already in VMEM.  Returns scalar contribution."""
    if mix == "load_only":
        # touch one lane only: the DMA moved the whole tile, the VPU does ~nothing
        return blk[0, 0]
    if mix == "load_sum":
        if interleave == 1:
            return jnp.sum(blk)
        # `interleave` independent per-chunk accumulator chains, combined
        # only at the end (same elements summed; shorter dependence chains)
        rr = blk.shape[0] // interleave
        parts = [jnp.sum(blk[j * rr:(j + 1) * rr]) for j in range(interleave)]
        s = parts[0]
        for p in parts[1:]:
            s = s + p
        return s
    if mix == "fma":
        v = blk
        a = jnp.float32(1.0000001)
        b = jnp.float32(1e-9)
        for _ in range(depth):
            v = v * a + b
        return jnp.sum(v)
    if mix == "mxu":
        y = jnp.dot(blk, w, preferred_element_type=jnp.float32)
        return jnp.sum(y[:1, :1])
    raise KeyError(mix)


def _acc_kernel(mix: str, depth: int, interleave: int, *refs):
    # refs order: (x_ref[, w_ref], o_ref)
    x_ref, o_ref = refs[0], refs[-1]
    w_ref = refs[1] if mix == "mxu" else None
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[0, 0] = jnp.float32(0.0)

    blk = x_ref[...].astype(jnp.float32)
    wv = w_ref[...].astype(jnp.float32) if w_ref is not None else None
    o_ref[0, 0] += _mix_body(mix, depth, blk, wv, interleave)


def _copy_kernel(interleave, x_ref, o_ref):
    if interleave == 1:
        o_ref[...] = x_ref[...]
        return
    # per-chunk stores: `interleave` independent copy streams inside one tile
    rr = x_ref.shape[0] // interleave
    for j in range(interleave):
        o_ref[j * rr:(j + 1) * rr, :] = x_ref[j * rr:(j + 1) * rr, :]


def _triad_kernel(b_ref, c_ref, o_ref):
    """STREAM triad a = b + s*c per tile (2 read streams, 1 write stream)."""
    o_ref[...] = b_ref[...] + jnp.asarray(1.5, b_ref.dtype) * c_ref[...]


def _rw_kernel(reads, writes, interleave, *refs):
    """R:W ratio tile: fold R read tiles triad-style (v = s0 + c*s1 + ...),
    store v to each of W output tiles — the same ratio the xla oracle (k_rw)
    emits, inside one grid program.  refs: R in-refs then W out-refs.
    ``interleave`` > 1 folds each of the tile's row chunks independently
    (identical values — chunked folds of an elementwise combine — with
    shorter per-chunk dependence chains)."""
    from repro.bench.mixes import RW_COMBINE_COEF
    rr = refs[0].shape[0] // interleave
    chunks = []
    for j in range(interleave):
        sl = slice(j * rr, (j + 1) * rr) if interleave > 1 else ...
        v = refs[0][sl]
        coef = jnp.asarray(RW_COMBINE_COEF, v.dtype)
        for r in range(1, reads):
            v = v + coef * refs[r][sl]
        chunks.append((sl, v))
    for w in range(writes):
        for sl, v in chunks:
            refs[reads + w][sl] = v


def _chase_kernel(x_ref, o_ref):
    """Latency probe tile: x_ref is an int32 (rows, lanes) tile holding one
    full permutation cycle of TILE-LOCAL flat indices; walk it end to end
    (``j = flat[j]``) so every load's address is the previous load's value —
    dependent loads the pipeline cannot overlap.  The final index folds into
    the revisited (1, 1) accumulator, keeping the whole chain live."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[0, 0] = jnp.float32(0.0)

    flat = x_ref[...].reshape(-1)
    j = jax.lax.fori_loop(0, flat.shape[0], lambda _, jj: flat[jj],
                          jnp.int32(0))
    o_ref[0, 0] += j.astype(jnp.float32)


def _stream_index_map(streams: int, n_blocks: int):
    """Block visit order: i -> interleaved across `streams` equal segments.
    streams=1 is the sequential (single-pointer) walk."""
    seg = n_blocks // streams

    def index_map(i):
        return (jax.lax.rem(i, streams) * seg + i // streams, 0)

    return index_map


def membench_call(x, *, mix: str = "load_sum", depth: int = 8,
                  block_rows: int = 128, streams: int = 1,
                  interpret: bool = True, y=None, ys=(),
                  interleave: int = 1):
    """x: (rows, 128) f32/bf16; returns scalar (load-family) or array (copy /
    triad) or tuple-of-arrays (rw family) output.  ``triad`` needs a second
    same-shape operand ``y``; ``rw_RtoW`` needs its R-1 extra read streams as
    ``ys`` and returns its W outputs as a tuple.  ``interleave`` splits each
    VMEM tile into independent row-chunk dependence chains (load_sum / copy /
    rw only — the bench backend gates the rest)."""
    rows, lanes = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    n_blocks = rows // block_rows
    assert n_blocks % streams == 0, (n_blocks, streams)
    if interleave > 1:
        assert mix in ("load_sum", "copy") or mix.startswith("rw_"), \
            f"mix {mix!r} has no interleaved variant"
        assert block_rows % interleave == 0, (block_rows, interleave)
    imap = _stream_index_map(streams, n_blocks)

    in_specs = [pl.BlockSpec((block_rows, lanes), imap)]
    operands = [x]
    base_mix = "fma" if mix.startswith("fma") else \
        ("rw" if mix.startswith("rw_") else mix)

    if base_mix == "rw":
        # one grid program emitting R tile-loads + W tile-stores per step
        from repro.bench.mixes import get_mix
        reads, writes = get_mix(mix).rw
        assert len(ys) == reads - 1, (mix, len(ys))
        assert all(s.shape == x.shape for s in ys), mix
        return pl.pallas_call(
            functools.partial(_rw_kernel, reads, writes, interleave),
            grid=(n_blocks,),
            in_specs=in_specs * reads,
            out_specs=tuple(pl.BlockSpec((block_rows, lanes), imap)
                            for _ in range(writes)),
            out_shape=tuple(jax.ShapeDtypeStruct(x.shape, x.dtype)
                            for _ in range(writes)),
            interpret=interpret,
        )(x, *ys)
    if base_mix == "mxu":
        w = jnp.eye(lanes, dtype=x.dtype)
        in_specs.append(pl.BlockSpec((lanes, lanes), lambda i: (0, 0)))
        operands.append(w)

    if base_mix == "latency_chase":
        # x is the int32 permutation buffer (see core.instruction_mix
        # .chase_perm with parts = rows / block_rows): one pointer cycle per
        # VMEM tile, walked serially inside the grid program
        return pl.pallas_call(
            _chase_kernel,
            grid=(n_blocks,),
            in_specs=in_specs[:1],
            out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
            interpret=interpret,
        )(x)[0, 0]

    if base_mix == "copy":
        return pl.pallas_call(
            functools.partial(_copy_kernel, interleave),
            grid=(n_blocks,),
            in_specs=in_specs[:1],
            out_specs=pl.BlockSpec((block_rows, lanes), imap),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x)

    if base_mix == "triad":
        assert y is not None and y.shape == x.shape, "triad needs y of x.shape"
        return pl.pallas_call(
            _triad_kernel,
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec((block_rows, lanes), imap),
                      pl.BlockSpec((block_rows, lanes), imap)],
            out_specs=pl.BlockSpec((block_rows, lanes), imap),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(x, y)

    kern = functools.partial(_acc_kernel, base_mix, depth, interleave)
    return pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(*operands)[0, 0]
