"""Pure-jnp oracles for the membench Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp


def ref_load_only(x):
    return x.astype(jnp.float32)[0, 0]


def ref_load_sum(x):
    return jnp.sum(x.astype(jnp.float32))


def ref_copy(x):
    return x


def ref_triad(x, y):
    return x + jnp.asarray(1.5, x.dtype) * y


def ref_fma(x, depth: int):
    v = x.astype(jnp.float32)
    a = jnp.float32(1.0000001)
    b = jnp.float32(1e-9)
    for _ in range(depth):
        v = v * a + b
    return jnp.sum(v)


def ref_mxu(x, block_rows: int):
    """Per-block (rows,128)@(128,128)->sum of [0,0] column block, accumulated."""
    rows, lanes = x.shape
    w = jnp.eye(lanes, dtype=x.dtype)
    total = jnp.float32(0.0)
    for i in range(rows // block_rows):
        blk = x[i * block_rows:(i + 1) * block_rows].astype(jnp.float32)
        y = jnp.dot(blk, w.astype(jnp.float32))
        total = total + y[0, 0]
    return total


def reference(mix: str, x, depth: int = 8, block_rows: int = 128, y=None):
    if mix == "load_only":
        # accumulated over blocks: one lane per block
        rows = x.shape[0]
        n = rows // block_rows
        idx = [i * block_rows for i in range(n)]
        return jnp.sum(x.astype(jnp.float32)[jnp.array(idx), 0])
    if mix == "load_sum":
        return ref_load_sum(x)
    if mix == "copy":
        return ref_copy(x)
    if mix == "triad":
        return ref_triad(x, y)
    if mix.startswith("fma"):
        return ref_fma(x, depth)
    if mix == "mxu":
        return ref_mxu(x, block_rows)
    raise KeyError(mix)
