"""jit'd wrappers + work accounting for the membench Pallas kernels.

Accounting delegates to the shared mix registry (``repro.bench.mixes``) so the
Pallas path and the XLA oracles can never disagree about bytes/flops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.membench.membench import membench_call


def _split_mix(mix: str, depth: int) -> tuple[str, int]:
    """'fma_4' -> ('fma', 4); other names pass through with default depth."""
    if mix.startswith("fma_"):
        return "fma", int(mix.split("_")[1])
    return mix, depth


def make_kernel(mix: str = "load_sum", depth: int = 8, block_rows: int = 128,
                streams: int = 1, interpret: bool = True,
                interleave: int = 1):
    """Returns jit'd fn(x) -> jax array (scalar or array output).

    ``triad`` returns fn(x, y) — two read streams, one write stream.
    ``interleave`` > 1 splits each VMEM tile into independent row-chunk
    dependence chains (load_sum / copy / rw only).
    """
    base_mix, depth_eff = _split_mix(mix, depth)

    if base_mix == "triad":
        @jax.jit
        def fn2(x, y):
            return membench_call(x, mix="triad", depth=depth_eff,
                                 block_rows=block_rows, streams=streams,
                                 interpret=interpret, y=y)
        return fn2

    if mix.startswith("rw_"):
        @jax.jit
        def fnr(x, *ys):
            return membench_call(x, mix=mix, depth=depth_eff,
                                 block_rows=block_rows, streams=streams,
                                 interpret=interpret, ys=ys,
                                 interleave=interleave)
        return fnr

    @jax.jit
    def fn(x):
        return membench_call(x, mix=base_mix, depth=depth_eff,
                             block_rows=block_rows, streams=streams,
                             interpret=interpret, interleave=interleave)

    return fn


def make_timed_kernel(mix: str = "load_sum", depth: int = 8,
                      block_rows: int = 128, streams: int = 1,
                      interpret: bool = True, passes: int = 1,
                      unroll: int = 1, interleave: int = 1,
                      load: int = 0):
    """Like make_kernel, but loops ``passes`` times over the buffer inside one
    compiled call (the paper's measurement loop) so dispatch overhead does not
    swamp cache-resident working sets.  A one-element self-dependent
    perturbation chains the iterations (defeats loop-invariant hoisting, as in
    the XLA oracles).  ``unroll`` runs that many chained kernel sweeps per
    loop trip (``core.instruction_mix._pass_loop`` — the same unroll
    discipline as the oracles, so accounting parity holds by construction).
    Always returns a scalar fn — fn(x), or fn(x, y) for ``triad``.

    Mixes whose kernel produces array outputs (copy / triad / rw) loop-carry
    those outputs through the pass loop with ROTATING per-sweep slots
    (``core.instruction_mix._rotating_pass_loop``): while-loop state must be
    fully materialized every iteration, and one slot per unrolled sweep
    means EVERY sweep's outputs are loop state — interpret-mode XLA can
    narrow neither the whole timed sweep down to the one element the
    accumulator consumes (the repro.audit DCE finding,
    ``tests/data/hlo/dce_pallas_copy.txt``) nor the interior unrolled sweeps
    (the dead-interior-sweep finding,
    ``tests/data/hlo/dead_sweep_xla_copy_u4.txt``).  On real TPU the opaque
    pallas_call never had either hazard, and the slots only alias the output
    buffers the kernel writes anyway.

    ``load`` > 0 (``latency_chase`` only — the bench spec gates it) builds
    the loaded-latency composite fn(perm, gen): each probe pass is followed
    by ``load * GEN_SWEEPS_PER_PASS`` load_sum generator sweeps of ``gen``,
    chained through the accumulator — the same time-shared emulation as the
    xla oracle ``k_chase_loaded``, so accounting parity holds.
    """
    from repro.core.instruction_mix import (_consume_slots, _pass_loop,
                                            _rotating_pass_loop)
    base_mix, _ = _split_mix(mix, depth)
    one = make_kernel(mix, depth=depth, block_rows=block_rows,
                      streams=streams, interpret=interpret,
                      interleave=interleave)

    def _chain(x, r, acc):
        val = r if getattr(r, "ndim", 0) == 0 else r.reshape(-1)[0]
        acc = acc + val.astype(jnp.float32)
        eps = (acc * 1e-30).astype(x.dtype).reshape(())
        return x.at[(0,) * x.ndim].add(eps), acc

    def _perturb(t, acc):
        eps = (acc * 1e-30).astype(t.dtype).reshape(())
        return t.at[(0,) * t.ndim].add(eps)

    def _carried(call, x, extra):
        """Pass loop with the kernel outputs in rotating per-sweep carry
        slots — every unrolled sweep's outputs stay live loop state (the
        liveness mechanism; an ``optimization_barrier`` here demonstrably
        does NOT survive XLA:CPU optimization)."""
        out0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            jax.eval_shape(call, x, *extra))

        def sweep(_, state, _outs):
            x, extra, acc = state
            outs = call(x, *extra)
            for o in jax.tree.leaves(outs):
                x, acc = _chain(x, o, acc)
            # Extra read streams must be perturbed too: a loop-invariant
            # operand lets XLA hoist its arithmetic (e.g. triad's a*y scale)
            # out of the timed loop, halving the executed flops.
            extra = tuple(_perturb(e, acc) for e in extra)
            return (x, extra, acc), outs

        (_, _, acc), slots = _rotating_pass_loop(
            sweep, passes, unroll, (x, tuple(extra), jnp.float32(0)), out0)
        return _consume_slots(acc, slots)

    if base_mix == "triad":
        @jax.jit
        def fn2(x, y):
            return _carried(one, x, (y,))
        return fn2

    if mix.startswith("rw_"):
        @jax.jit
        def fnr(x, *ys):
            return _carried(one, x, ys)
        return fnr

    if base_mix == "copy":
        @jax.jit
        def fnc(x):
            return _carried(one, x, ())
        return fnc

    if base_mix == "latency_chase" and load:
        from repro.bench.mixes import GEN_SWEEPS_PER_PASS
        gen_one = make_kernel("load_sum", depth=depth, block_rows=block_rows,
                              streams=streams, interpret=interpret)
        sweeps = load * GEN_SWEEPS_PER_PASS

        @jax.jit
        def fnl(x, g):         # x: int32 perm buffer; g: generator buffer
            def gsweep(_, c):
                g, acc = c
                return _chain(g, gen_one(g), acc)

            def body(_, carry):
                x, g, acc = carry
                # _chain's eps converts to x's int32 dtype, truncating the
                # tiny float to 0 — a value-preserving, data-dependent write
                # that keeps the perm cycle intact while chaining passes
                x, acc = _chain(x, one(x), acc)
                g, acc = jax.lax.fori_loop(0, sweeps, gsweep, (g, acc))
                return (x, g, acc)

            _, _, acc = _pass_loop(body, passes, unroll,
                                   (x, g, jnp.float32(0)))
            return acc

        return fnl

    @jax.jit
    def fn(x):                 # scalar-output mixes: nothing to narrow
        def body(_, carry):
            x, acc = carry
            x, acc = _chain(x, one(x), acc)
            return (x, acc)
        _, acc = _pass_loop(body, passes, unroll, (x, jnp.float32(0)))
        return acc

    return fn


def work_per_call(mix: str, x, depth: int = 8) -> tuple[float, float]:
    """(bytes, flops) moved/executed by one kernel invocation — straight from
    the shared mix registry."""
    from repro.bench import mixes as mixreg
    name = mix
    if mix == "fma":
        name = f"fma_{depth}"
    m = mixreg.get_mix(name)
    return m.bytes_per_pass(x.size * x.dtype.itemsize), m.flops_per_pass(x.size)
