"""jit'd wrappers + work accounting for the membench Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.membench.membench import membench_call


def make_kernel(mix: str = "load_sum", depth: int = 8, block_rows: int = 128,
                streams: int = 1, interpret: bool = True):
    """Returns jit'd fn(x) -> jax array (scalar or copy output)."""
    depth_eff = depth
    if mix.startswith("fma_"):
        depth_eff = int(mix.split("_")[1])
        mix = "fma"

    @jax.jit
    def fn(x):
        return membench_call(x, mix=mix, depth=depth_eff,
                             block_rows=block_rows, streams=streams,
                             interpret=interpret)

    return fn


def work_per_call(mix: str, x, depth: int = 8) -> tuple[float, float]:
    """(bytes, flops) moved/executed by one kernel invocation."""
    nbytes = float(x.size * x.dtype.itemsize)
    n = float(x.size)
    if mix == "load_only":
        return nbytes, 0.0
    if mix == "load_sum":
        return nbytes, n
    if mix == "copy":
        return 2 * nbytes, 0.0
    if mix.startswith("fma"):
        d = int(mix.split("_")[1]) if "_" in mix else depth
        return nbytes, 2.0 * d * n
    if mix == "mxu":
        return nbytes, 2.0 * 128 * n
    raise KeyError(mix)
