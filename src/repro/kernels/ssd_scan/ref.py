"""Oracle for the SSD kernel: sequential state-space recurrence in f64-ish f32."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference(xdt, dA, Bm, Cm):
    """Token-by-token recurrence: h_t = exp(dA_t) h_{t-1} + B_t x_t^T;
    y_t = C_t . h_t.  xdt: (BH, S, P); dA: (BH, S); Bm/Cm: (BH, S, N)."""
    BH, S, P = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, dA_t, b_t, c_t = inp
        h = jnp.exp(dA_t)[:, None, None] * h + \
            b_t[:, :, None] * x_t[:, None, :]          # (BH, N, P)
        y = jnp.einsum("bn,bnp->bp", c_t, h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (xdt.astype(jnp.float32).swapaxes(0, 1),
          dA.astype(jnp.float32).swapaxes(0, 1),
          Bm.astype(jnp.float32).swapaxes(0, 1),
          Cm.astype(jnp.float32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h
