"""Mamba2 SSD — Pallas TPU kernel (full forward: intra-chunk + recurrence).

Grid (B*H, n_chunks) with the chunk axis innermost: TPU grids execute
sequentially per core, so the running SSM state lives in VMEM scratch across
chunk steps and the inter-chunk recurrence costs no extra HBM traffic — the
kernel fuses what the XLA path does as einsums + a lax.scan.  This is the
hardware-adaptation story of DESIGN.md §2: the A64FX insight "keep the
load/store units saturated" becomes "keep the chunk state VMEM-resident".

Layout: per-head streams (B*H, S, ·) so one grid row owns one head's sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(nc: int, x_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, state_scr):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P) already dt-weighted
    dA = dA_ref[0].astype(jnp.float32)        # (Q,)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    cum = jnp.cumsum(dA)                      # (Q,)
    seg = cum[:, None] - cum[None, :]         # (Qi, Qj)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Qi, Qj)
    y = jax.lax.dot_general(CB * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # off-diagonal: contribution of the carried state
    state = state_scr[...]                    # (N, P)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: state' = exp(cum_last) * state + sum_j decay_out_j B_j x_j^T
    decay_out = jnp.exp(cum[-1] - cum)        # (Q,)
    upd = jax.lax.dot_general(B * decay_out[:, None], x,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state_scr[...] = state * jnp.exp(cum[-1]) + upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _():
        st_ref[0] = state_scr[...].astype(st_ref.dtype)


def ssd_scan(xdt, dA, Bm, Cm, *, chunk: int = 256, interpret: bool = True):
    """xdt: (BH, S, P) dt-weighted inputs; dA: (BH, S); Bm/Cm: (BH, S, N).

    Returns (y (BH, S, P), final_state (BH, N, P)).
    """
    BH, S, P = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    kern = functools.partial(_ssd_kernel, nc)
    y, st = pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), xdt.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
    return y, st
