"""jit'd wrapper for the SSD kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xdt, dA, Bm, Cm, chunk: int = 256, interpret: bool = True):
    return ssd_scan(xdt, dA, Bm, Cm, chunk=chunk, interpret=interpret)


def flops(BH: int, S: int, P: int, N: int, chunk: int) -> float:
    """Per forward: intra 2*Q*Q*(N+P) + state 2*Q*N*P + off 2*Q*N*P per chunk."""
    nc = S // chunk
    per_chunk = 2 * chunk * chunk * (N + P) + 4 * chunk * N * P
    return float(BH * nc * per_chunk)
