"""Resilient training loop: checkpoint/resume, SIGTERM emergency save,
straggler monitoring, elastic restart.

The loop is deliberately plain python around one pjit'd step — every
production concern (resume, async save, drift detection, preemption) lives
out here where it can be unit-tested on CPU meshes.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import make_pipeline
from repro.distributed.sharding import ShardCtx
from repro.ft.stragglers import StepTimer
from repro.models.common import abstract_params, init_params, logical_axes
from repro.models.registry import build
from repro.models.variant import BASELINE, Variant
from repro.optim import adamw
from repro.train.step import make_train_step


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    seed: int = 0
    async_ckpt: bool = True
    grad_compression: bool = False   # int8 error-feedback DP gradient reduce
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


class Trainer:
    def __init__(self, arch_cfg, shape, mesh, tcfg: TrainConfig,
                 variant: Variant = BASELINE):
        self.cfg = arch_cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.variant = variant
        self.ctx = ShardCtx(mesh)
        self.model = build(arch_cfg)
        self.pipeline = make_pipeline(arch_cfg, shape, self.ctx, seed=tcfg.seed)
        self.step_timer = StepTimer()
        self._interrupted = False

        specs = self.model.param_specs()
        self.p_shardings = self.ctx.tree_shardings(abstract_params(specs),
                                                   logical_axes(specs))
        self.step_fn = jax.jit(
            make_train_step(arch_cfg, self.ctx, opt_cfg=tcfg.opt,
                            variant=variant,
                            grad_compression=tcfg.grad_compression),
            donate_argnums=(0, 1))

    # -- state --------------------------------------------------------------
    def init_state(self, rng=None):
        rng = rng if rng is not None else jax.random.key(self.tcfg.seed)
        specs = self.model.param_specs()
        params = init_params(specs, rng)
        params = jax.tree.map(jax.device_put, params,
                              self.p_shardings)
        opt_state = adamw.init_state(params)
        if self.tcfg.grad_compression:
            from repro.optim.compression import init_error
            opt_state["ef_error"] = init_error(params)
        return params, opt_state, 0

    def restore_or_init(self):
        """Elastic resume: restores onto the *current* mesh regardless of the
        mesh the checkpoint was written on."""
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        params, opt_state, start = self.init_state()
        if step is None:
            return params, opt_state, 0
        opt_sh = {"mu": self.p_shardings, "nu": self.p_shardings, "step": None}
        if "ef_error" in opt_state:
            opt_sh["ef_error"] = self.p_shardings
        tree_like = {"params": params, "opt": opt_state}
        shardings = {"params": self.p_shardings, "opt": opt_sh}
        restored, manifest = ckpt.restore(self.tcfg.ckpt_dir, tree_like,
                                          shardings)
        return restored["params"], restored["opt"], manifest["step"]

    # -- loop ---------------------------------------------------------------
    def _handle_sigterm(self, *_):
        self._interrupted = True

    def train(self, resume: bool = True):
        tcfg = self.tcfg
        if resume:
            params, opt_state, start = self.restore_or_init()
        else:
            params, opt_state, start = self.init_state()
        old = signal.signal(signal.SIGTERM, self._handle_sigterm)
        history = []
        try:
            with jax.set_mesh(self.mesh):
                for step in range(start, tcfg.steps):
                    batch = self.pipeline.batch(step)
                    t0 = time.perf_counter()
                    params, opt_state, metrics = self.step_fn(params, opt_state,
                                                              batch)
                    jax.block_until_ready(metrics["loss"])
                    dt = time.perf_counter() - t0
                    slow = self.step_timer.update(step, dt)
                    if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                        m = {k: float(v) for k, v in metrics.items()}
                        history.append({"step": step, "dt": dt, **m})
                        print(f"step {step:5d} loss={m['loss']:.4f} "
                              f"gnorm={m.get('grad_norm', 0):.3f} "
                              f"dt={dt*1e3:.0f}ms{' SLOW' if slow else ''}")
                    if self._interrupted:
                        print("SIGTERM: emergency checkpoint")
                        ckpt.save(tcfg.ckpt_dir, step + 1,
                                  {"params": params, "opt": opt_state},
                                  blocking=True)
                        break
                    if (step + 1) % tcfg.ckpt_every == 0:
                        ckpt.save(tcfg.ckpt_dir, step + 1,
                                  {"params": params, "opt": opt_state},
                                  extra={"arch": self.cfg.name},
                                  blocking=not tcfg.async_ckpt)
            ckpt.wait_async()
        finally:
            signal.signal(signal.SIGTERM, old)
        return params, opt_state, history
