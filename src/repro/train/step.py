"""Training / serving step factories (the functions the dry-run lowers).

``make_train_step``: value_and_grad over the model loss + AdamW, with optional
gradient accumulation (scanned microbatches) and optional int8 error-feedback
gradient compression on the data-parallel reduction.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import build
from repro.models.variant import BASELINE, Variant
from repro.optim import adamw


def make_train_step(cfg, ctx, opt_cfg: adamw.AdamWConfig | None = None,
                    variant: Variant = BASELINE, accum_steps: int | None = None,
                    grad_compression: bool = False):
    """grad_compression=True: int8 error-feedback quantization of gradients
    before the optimizer (models the compressed DP all-reduce; the error
    residual lives in opt_state["ef_error"])."""
    model = build(cfg)
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    accum_steps = accum_steps if accum_steps is not None else variant.accum_steps

    def loss_fn(params, batch):
        if variant.cast_params:
            # bf16 weights at step entry: every downstream FSDP all-gather
            # carries half the wire bytes (grads still flow f32 via the cast's
            # transpose).  1D params (norms/scales) stay f32.
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if (p.dtype == jnp.float32 and p.ndim > 1) else p, params)
        loss, metrics = model.loss(params, batch, ctx, variant)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            def micro(carry, mb):
                acc, = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, acc, g),), (l, m)
            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum,), (losses, metrics) = jax.lax.scan(micro, (zero,), micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        if grad_compression:
            from repro.optim.compression import compress_grads
            grads, new_err = compress_grads(grads, opt_state["ef_error"])
        new_params, new_opt, opt_metrics = adamw.apply(
            opt_cfg, params,
            {k: v for k, v in opt_state.items() if k != "ef_error"}, grads)
        if grad_compression:
            new_opt["ef_error"] = new_err
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg, ctx, variant: Variant = BASELINE):
    model = build(cfg)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            return model.prefill(params, batch, ctx, variant)
        return model.prefill(params, batch["tokens"], ctx, variant)

    return prefill_step


def make_decode_step(cfg, ctx, variant: Variant = BASELINE,
                     seq_shard_decode: bool = False):
    model = build(cfg)

    def decode_step(params, cache, batch, pos):
        kwargs = {}
        if cfg.family == "hybrid":
            kwargs["seq_shard_decode"] = seq_shard_decode
        logits, new_cache = model.decode_step(params, cache, batch["tokens"],
                                              pos, ctx, variant, **kwargs)
        return logits, new_cache

    return decode_step
