"""Sharding rule resolution: divisibility fallbacks, axis-usage chains."""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardCtx
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def ctx111():
    return ShardCtx(make_mesh((1, 1, 1), ("pod", "data", "model")))


def test_single_device_everything_replicated(ctx111):
    spec = ctx111.spec((256, 4096), ("batch", "seq"))
    assert spec == P()


def test_fallback_on_non_divisible():
    # heads=40 on a 16-way model axis must fall back to replication
    ctx = ShardCtx(make_mesh((1, 1, 1), ("pod", "data", "model")))
    assert ctx.resolve_dim("heads", 40) is None


def test_axis_used_once():
    """One mesh axis may shard only one dim of a tensor."""
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    ctx = ShardCtx(mesh)
    spec = ctx.spec((64, 64), ("heads", "ffn"))  # both want 'model'
    # on a 1-device mesh both resolve to None
    assert spec == P()


def test_kv_seq_fallback_chain_documented():
    """batch takes data first; kv_seq then falls through to model."""
    ctx = ShardCtx(make_mesh((1, 1, 1), ("pod", "data", "model")))
    rules = ctx.rules["kv_seq"]
    assert rules[0] == ("data",) and rules[1] == ("model",)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4096), st.sampled_from(["heads", "ffn", "vocab", "embed",
                                              "batch", "kv_seq"]))
def test_spec_never_crashes(size, logical):
    ctx = ShardCtx(make_mesh((1, 1, 1), ("pod", "data", "model")))
    spec = ctx.spec((size,), (logical,))
    assert isinstance(spec, P)


def test_tree_abstract_attaches_shardings(ctx111):
    import jax.numpy as jnp
    tree = {"a": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    axes = {"a": ("batch", "embed")}
    out = ctx111.tree_abstract(tree, axes)
    assert out["a"].sharding is not None
    assert out["a"].shape == (8, 16)


def test_param_specs_cover_all_leaves():
    """every model parameter must carry logical axes of matching rank."""
    from repro.configs import get_arch, list_archs, reduced
    from repro.models.common import abstract_params, logical_axes
    from repro.models.registry import build
    for name in list_archs():
        model = build(reduced(get_arch(name)))
        specs = model.param_specs()
        flat_abs = jax.tree.leaves(abstract_params(specs))
        flat_axes = jax.tree.leaves(logical_axes(specs),
                                    is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_abs) == len(flat_axes)
        for sds, ax in zip(flat_abs, flat_axes):
            assert len(sds.shape) == len(ax), (name, sds.shape, ax)
