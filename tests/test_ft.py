"""Fault tolerance: checkpoint roundtrip/publish, error-feedback compression,
straggler detection, optimizer convergence."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

from repro.checkpoint import checkpoint as ckpt
from repro.ft.stragglers import StepTimer, probe_devices
from repro.optim import adamw
from repro.optim.compression import compress_grads, dequantize, init_error, quantize


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(key):
    ks = jax.random.split(key, 3)
    return {"w": {"a": jax.random.normal(ks[0], (16, 8)),
                  "b": jax.random.normal(ks[1], (4,))},
            "step_arr": jnp.arange(5)}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    ckpt.save(tmp_path, 7, tree)
    restored, manifest = ckpt.restore(tmp_path, tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_multiple(tmp_path):
    t1, t2 = _tree(jax.random.key(1)), _tree(jax.random.key(2))
    ckpt.save(tmp_path, 10, t1)
    ckpt.save(tmp_path, 20, t2)
    assert ckpt.latest_step(tmp_path) == 20
    restored, _ = ckpt.restore(tmp_path, t2, step=10)
    np.testing.assert_array_equal(np.asarray(restored["w"]["a"]),
                                  np.asarray(t1["w"]["a"]))


def test_checkpoint_async(tmp_path):
    tree = _tree(jax.random.key(3))
    ckpt.save(tmp_path, 5, tree, blocking=False)
    ckpt.wait_async()
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_torn_write_fallback(tmp_path):
    tree = _tree(jax.random.key(4))
    ckpt.save(tmp_path, 5, tree)
    # corrupt LATEST to point at a missing dir (simulated preemption mid-publish)
    (Path(tmp_path) / "LATEST").write_text("step_99999999")
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_structure_mismatch_detected(tmp_path):
    ckpt.save(tmp_path, 1, _tree(jax.random.key(5)))
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, {"different": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# gradient compression (int8 error feedback)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=1e-4, max_value=1e3))
def test_quantize_roundtrip_bounded(scale_mag):
    g = jnp.array([0.5, -1.0, 0.25, 1.0]) * scale_mag
    q, s = quantize(g)
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 * (1 + 1e-5)  # half-ulp of the int8 grid


def test_error_feedback_preserves_signal():
    """Sum of compressed grads over steps tracks the true sum (EF property)."""
    true_g = jnp.full((64,), 0.001)          # tiny gradient, below 1 int8 ulp
    grads = {"w": true_g}
    err = init_error(grads)
    total = jnp.zeros((64,))
    for _ in range(100):
        cg, err = compress_grads(grads, err)
        total = total + cg["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(true_g * 100),
                               rtol=0.15)


def test_compressed_sgd_converges():
    """SGD on a quadratic with int8 EF compression still converges."""
    w = jnp.array([5.0, -3.0, 2.0])
    target = jnp.array([1.0, 1.0, 1.0])
    err = init_error({"w": w})
    for _ in range(300):
        g = {"w": 2 * (w - target)}
        cg, err = compress_grads(g, err)
        w = w - 0.05 * cg["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

def test_probe_devices_runs():
    probes = probe_devices(nbytes=256 * 1024, passes=2, reps=2)
    assert len(probes) == len(jax.devices())
    assert all(p.gbps > 0 for p in probes)


def test_step_timer_flags_outlier():
    t = StepTimer(z_threshold=3.0)
    for i in range(20):
        t.update(i, 0.1 + 0.001 * (i % 3))
    assert t.update(20, 1.0) is True        # 10x step time => straggler
    assert t.slow_steps and t.slow_steps[-1][0] == 20


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, grad_clip=100.0)
    params = {"w": jnp.array([4.0, -2.0])}
    state = adamw.init_state(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply(cfg, params, state, g)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    _, _, m = adamw.apply(cfg, params, state, {"w": jnp.full(3, 100.0)})
    assert float(m["grad_norm"]) > 1.0       # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in [1, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < 1.0 and lrs[4] == pytest.approx(0.1, abs=0.02)


def test_bf16_moment_storage():
    cfg = adamw.AdamWConfig()
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    state = {"mu": jax.tree.map(lambda x: x.astype(jnp.bfloat16), state["mu"]),
             "nu": jax.tree.map(lambda x: x.astype(jnp.bfloat16), state["nu"]),
             "step": state["step"]}
    _, new_state, _ = adamw.apply(cfg, params, state, {"w": jnp.ones(4)})
    assert new_state["mu"]["w"].dtype == jnp.bfloat16
