"""The sharded multi-device backend: cross-backend accounting parity vs xla,
the devices knob through spec/result round-trips, the weak-scaling curve on 8
forced host devices (subprocess — tests see 1 device by design, see
conftest.py), and compiled-case cache behavior across device counts."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.bench import BenchSpec, BenchSpecError, BenchResult, Runner, mix_names

SRC = str(Path(__file__).resolve().parents[1] / "src")
TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1, passes=1)


# ---------------------------------------------------------------------------
# single-device (in-process): parity, validation, round-trips
# ---------------------------------------------------------------------------

def test_sharded_accounting_parity_vs_xla():
    """Every xla-runnable mix runs sharded at devices=1 with byte-identical
    bytes/flops accounting (both read the shared registry)."""
    runner = Runner()
    for name in mix_names("xla"):
        acct = {}
        for backend in ("xla", "sharded"):
            spec = BenchSpec(mixes=(name,), backend=backend, **TINY)
            (pt,) = runner.run(spec).points
            assert pt.gbps > 0 and pt.mean_s > 0, (name, backend)
            acct[backend] = (pt.bytes_per_call, pt.flops_per_call)
        assert acct["xla"] == acct["sharded"], (name, acct)


def test_sharded_supports_exactly_the_xla_mixes():
    assert mix_names("sharded") == mix_names("xla")
    with pytest.raises(BenchSpecError):    # load_only is pallas-only
        BenchSpec(mixes=("load_only",), backend="sharded", **TINY)


def test_sharded_rejects_more_devices_than_visible():
    """conftest guarantees this process sees one device."""
    spec = BenchSpec(mixes=("load_sum",), backend="sharded", devices=2, **TINY)
    with pytest.raises(BenchSpecError, match="devices=2"):
        Runner().run(spec)


def test_sharded_knob_rules_match_xla():
    """The per-shard kernels are the oracles, so the oracle knob rules hold."""
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("copy",), backend="sharded", streams=2,
                               **TINY))
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("load_sum",), backend="sharded",
                               streams=2, block_rows=8, **TINY))


def test_sharded_point_carries_devices_and_roundtrips(tmp_path):
    spec = BenchSpec(mixes=("load_sum",), backend="sharded", devices=1, **TINY)
    res = Runner().run(spec)
    (pt,) = res.points
    assert pt.devices == 1 and pt.backend == "sharded"
    path = tmp_path / "res.json"
    res.to_json(path)
    back = BenchResult.from_json(path)
    assert back.points == res.points
    assert back.spec["devices"] == 1


# ---------------------------------------------------------------------------
# 8 forced host devices (subprocess)
# ---------------------------------------------------------------------------

SHARDED_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.bench import BenchSpec, BenchResult, Runner

per_dev = 256 * 2**10
runner = Runner()
specs = [BenchSpec(mixes=("load_sum",), sizes=(per_dev * k,),
                   backend="sharded", devices=k, passes=2, reps=2, warmup=1)
         for k in (1, 2, 4, 8)]
res = runner.run_many(specs)

# one point per device count, each stamped with its knob
assert [p.devices for p in res.points] == [1, 2, 4, 8], res.points
assert all(p.gbps > 0 for p in res.points)
assert res.meta["sizes"] == [per_dev * k for k in (1, 2, 4, 8)]

# speedup curve shape: anchored at 1.0 on devices=1, finite and positive
rels = res.baseline_relative(group_key=lambda p: p.mix)
assert abs(rels[0][1] - 1.0) < 1e-9, rels[0]
assert all(r > 0 for _, r in rels), rels
assert [p.devices for p, _ in rels] == sorted(p.devices for p, _ in rels)

# devices knob round-trips through the serialized result
back = BenchResult.from_dict(json.loads(res.to_json()))
assert [p.devices for p in back.points] == [1, 2, 4, 8]
assert [s["devices"] for s in back.spec["many"]] == [1, 2, 4, 8]

# compiled-case cache: re-running the sweep re-traces nothing
misses = runner.cache_misses
rerun = runner.run_many(specs)
assert runner.cache_misses == misses, (runner.cache_misses, misses)
assert runner.cache_hits >= len(specs)
# ... and the counters surface in the result envelope (schema v6 obs
# block): the rerun is all hits, and the runner-cumulative block carries
# the Runner's lifetime totals
obs = rerun.meta["obs"]
assert obs["counters"]["cache_hits"] >= len(specs), obs
assert obs["counters"].get("cache_misses", 0) == 0, obs
assert obs["runner"] == {"cache_hits": runner.cache_hits,
                         "cache_misses": runner.cache_misses}, obs

# legacy wrapper rides the same backend (no measurement loop of its own)
from repro.core.scaling import scaling_curve
pts = scaling_curve(per_dev, device_counts=[1, 2], passes=2, reps=2)
assert [p.devices for p in pts] == [1, 2] and pts[0].speedup == 1.0

print("SHARDED_OK", [round(p.gbps, 2) for p in res.points])
"""


def test_sharded_scaling_8dev_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARDED_SNIPPET],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout
