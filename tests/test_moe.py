"""MoE layer: conservation, capacity, aux loss, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_arch, reduced
from repro.distributed.sharding import make_smoke_ctx
from repro.models.common import init_params
from repro.models.moe import moe_layer, moe_specs

CTX = make_smoke_ctx()


def _moe_setup(top_k=1, n_experts=4, cf=8.0):
    cfg = reduced(get_arch("deepseek-v2-236b"))
    cfg = replace(cfg, moe=replace(cfg.moe, top_k=top_k, n_experts=n_experts,
                                   capacity_factor=cf, n_shared_experts=0))
    params = init_params(moe_specs(cfg), jax.random.key(0))
    return cfg, params


def _dense_expert_oracle(cfg, p, x):
    """Route each token to its argmax expert with NO capacity limit."""
    T, D = x.shape
    xc = x.astype(jnp.bfloat16)
    logits = (xc @ p["router"].astype(jnp.bfloat16)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    eidx = jnp.argmax(probs, axis=-1)
    w = jnp.take_along_axis(probs, eidx[:, None], axis=1)[:, 0]
    w = w / w  # top-1 normalized weight == 1
    outs = []
    for t in range(T):
        e = int(eidx[t])
        g = xc[t] @ p["w_gate"][e].astype(jnp.bfloat16)
        u = xc[t] @ p["w_up"][e].astype(jnp.bfloat16)
        h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(jnp.bfloat16)
        outs.append((h @ p["w_down"][e].astype(jnp.bfloat16)).astype(jnp.float32))
    return jnp.stack(outs) * w[:, None]


def test_moe_matches_dense_oracle_top1():
    """top-1 with generous capacity == per-token dense expert compute."""
    cfg, params = _moe_setup(top_k=1, cf=8.0)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          jnp.bfloat16) * 0.5
    with jax.set_mesh(CTX.mesh):
        y, aux = jax.jit(lambda p, x: moe_layer(CTX, cfg, p, x))(params, x)
    ref = _dense_expert_oracle(cfg, params, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model), np.float32),
                               np.asarray(ref), rtol=5e-2, atol=5e-2)


def test_moe_capacity_drops_tokens():
    """capacity_factor -> 0 forces drops => output partially zero."""
    cfg, params = _moe_setup(top_k=1, cf=8.0)
    x = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model),
                          jnp.bfloat16) * 0.5
    with jax.set_mesh(CTX.mesh):
        y_full, _ = jax.jit(lambda p, x: moe_layer(CTX, cfg, p, x,
                                                   capacity_factor=8.0))(params, x)
        y_tight, _ = jax.jit(lambda p, x: moe_layer(CTX, cfg, p, x,
                                                    capacity_factor=0.1))(params, x)
    # tight capacity must zero-out some token outputs that full capacity kept
    full_nz = np.abs(np.asarray(y_full, np.float32)).sum(-1) > 1e-6
    tight_nz = np.abs(np.asarray(y_tight, np.float32)).sum(-1) > 1e-6
    assert tight_nz.sum() < full_nz.sum()


def test_moe_aux_loss_range():
    cfg, params = _moe_setup(top_k=2, cf=2.0)
    x = jax.random.normal(jax.random.key(3), (2, 64, cfg.d_model), jnp.bfloat16)
    with jax.set_mesh(CTX.mesh):
        _, aux = jax.jit(lambda p, x: moe_layer(CTX, cfg, p, x))(params, x)
    # balanced routing gives aux ~= E * K/E... switch aux: >= 1 (K normalization)
    assert 0.5 < float(aux) < float(cfg.moe.n_experts) * 2


def test_moe_deterministic():
    cfg, params = _moe_setup(top_k=2)
    x = jax.random.normal(jax.random.key(4), (1, 16, cfg.d_model), jnp.bfloat16)
    with jax.set_mesh(CTX.mesh):
        f = jax.jit(lambda p, x: moe_layer(CTX, cfg, p, x)[0])
        np.testing.assert_array_equal(np.asarray(f(params, x)),
                                      np.asarray(f(params, x)))


def test_moe_gradients_flow_to_experts_and_router():
    cfg, params = _moe_setup(top_k=2, cf=4.0)
    x = jax.random.normal(jax.random.key(5), (2, 16, cfg.d_model), jnp.bfloat16)

    def loss(p):
        y, aux = moe_layer(CTX, cfg, p, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + 0.01 * aux

    with jax.set_mesh(CTX.mesh):
        g = jax.jit(jax.grad(loss))(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0
