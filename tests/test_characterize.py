"""repro.characterize: detection on synthetic curves with known knees,
adaptive-driver convergence/economics, model fitting + serialization,
machine_model schema/registry/detect_host satellites, and consumer wiring
(roofline + autotune accept fitted models)."""
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro.characterize import (FittedMachineModel, adaptive_sweep,
                                characterize, crosscheck_prior,
                                detect_from_result, detect_levels,
                                fit_from_result, probe_sizes, render_markdown)
from repro.core.machine_model import (A64FX, ALTRA, THUNDERX2,
                                      MODEL_SCHEMA_VERSION, HardwareSpec,
                                      MachineModel, MemLevel, available_specs,
                                      detect_host, get_spec,
                                      parse_cache_size, register_spec)

DATA = Path(__file__).parent / "data"


# ---------------------------------------------------------------------------
# synthetic machines — ground truth the detector must recover
# ---------------------------------------------------------------------------

def staircase(levels):
    """[(capacity|None, gbps), ...] -> bw(size) step function."""
    def bw(size):
        for cap, g in levels:
            if cap is None or size <= cap:
                return g
        return levels[-1][1]
    return bw


TWO_LEVEL = [(256 * 2**10, 80.0), (None, 10.0)]
THREE_LEVEL = [(32 * 2**10, 120.0), (1 * 2**20, 60.0), (None, 12.0)]
FOUR_LEVEL = [(32 * 2**10, 150.0), (512 * 2**10, 90.0),
              (8 * 2**20, 40.0), (None, 9.0)]


def sample_curve(levels, lo=8 * 2**10, hi=128 * 2**20, n=48, noise=0.0,
                 seed=0):
    bw = staircase(levels)
    sizes = np.unique(np.geomspace(lo, hi, n).astype(np.int64))
    rng = np.random.default_rng(seed)
    g = np.array([bw(s) for s in sizes])
    if noise:
        g = g * (1.0 + rng.normal(0.0, noise, size=len(g)))
    return sizes, g


@pytest.mark.parametrize("truth", [TWO_LEVEL, THREE_LEVEL, FOUR_LEVEL],
                         ids=["2level", "3level", "4level"])
def test_detect_recovers_known_hierarchies(truth):
    sizes, g = sample_curve(truth, noise=0.02, seed=3)
    det = detect_levels(sizes, g)
    assert det.n_levels == len(truth)
    for lvl, (cap, gbps) in zip(det.levels, truth):
        # bandwidth: truth within the reported CI (plus a small tolerance
        # floor for the tiny-n plateaus)
        lo_ci, hi_ci = lvl.gbps_ci
        assert lo_ci - 0.1 * gbps <= gbps <= hi_ci + 0.1 * gbps, \
            (lvl.name, lvl.gbps_ci, gbps)
        if cap is None:
            assert lvl.capacity_bytes is None and lvl.capacity_ci is None
        else:
            # capacity: measured bracket must contain (or closely bracket)
            # the true boundary — the bracket's lower edge is the last size
            # that still fits, so truth >= lo and truth < ~hi
            lo_b, hi_b = lvl.capacity_ci
            assert lo_b <= cap <= hi_b * 1.05, (lvl.name, lvl.capacity_ci, cap)
            assert abs(math.log(lvl.capacity_bytes / cap)) < math.log(2.0)


def test_detect_noisy_plateaus_level_count_stable():
    for seed in range(4):
        sizes, g = sample_curve(THREE_LEVEL, noise=0.06, seed=seed)
        det = detect_levels(sizes, g)
        assert det.n_levels == 3, (seed, [l.gbps for l in det.levels])


def test_detect_degenerate_single_level():
    sizes, g = sample_curve([(None, 42.0)], noise=0.03, seed=1)
    det = detect_levels(sizes, g)
    assert det.n_levels == 1
    lvl = det.levels[0]
    assert lvl.name == "DRAM" and lvl.capacity_bytes is None
    assert lvl.gbps == pytest.approx(42.0, rel=0.05)
    assert det.boundaries == [] and det.unresolved(0.1) == []


def test_detect_rejects_bad_input():
    with pytest.raises(ValueError):
        detect_levels([], [])
    with pytest.raises(ValueError):
        detect_levels([1024, 2048], [10.0])
    with pytest.raises(ValueError):
        detect_levels([1024, 2048], [10.0, 0.0])


def test_detect_small_sample_counts():
    # detection must not crash below the filter/DP minimums
    for n in (1, 2, 3, 4):
        sizes, g = sample_curve(TWO_LEVEL, n=n)
        det = detect_levels(sizes, g)
        assert 1 <= det.n_levels <= 2


# ---------------------------------------------------------------------------
# synthetic runner — drives adaptive/fit/characterize hermetically
# ---------------------------------------------------------------------------

class _Pt:
    def __init__(self, nbytes, mix, gbps):
        self.nbytes, self.mix, self.gbps = nbytes, mix, gbps


class _Res:
    def __init__(self):
        self.points, self.meta = [], {}


class SyntheticRunner:
    """Duck-typed bench.Runner over a synthetic staircase machine."""
    PENALTY = {"load_sum": 1.0, "copy": 0.9, "fma_8": 0.7, "fma_32": 0.4}

    def __init__(self, levels=THREE_LEVEL, noise=0.02, seed=0):
        self.bw = staircase(levels)
        self.noise, self.seed = noise, seed
        self.calls = 0
        self.sizes_run: list[int] = []

    def run(self, spec):
        self.calls += 1
        rng = np.random.default_rng(self.seed + hash(spec.sizes) % 2**16)
        res = _Res()
        for nb in spec.sizes:
            self.sizes_run.append(nb)
            for m in spec.mixes:
                g = self.bw(nb) * self.PENALTY.get(m, 0.5) \
                    * (1.0 + rng.normal(0.0, self.noise))
                res.points.append(_Pt(nb, m, g))
        res.meta["sizes"] = list(spec.sizes)
        return res


def test_adaptive_converges_with_fewer_points_than_dense():
    r = SyntheticRunner(THREE_LEVEL)
    sw = adaptive_sweep("load_sum", runner=r, lo=16 * 2**10, hi=64 * 2**20,
                        resolution=0.10, coarse_per_decade=3, max_rounds=8)
    assert sw.converged
    assert sw.rounds <= 8
    assert sw.detection.n_levels == 3
    # strictly fewer measured sizes than the dense grid at this resolution
    assert sw.n_points < sw.dense_equivalent()
    # boundaries localized to the requested resolution — or to the buffer
    # tile floor (4 KiB per 8-row f32 step: brackets at small sizes can't
    # get relatively tighter than ~2 tile steps)
    for b in sw.detection.boundaries:
        floor = 2 * 4096 / b.lo
        assert b.resolved(max(0.10, floor)), (b.lo, b.hi, b.width)
    # and the true capacities sit inside the final brackets
    for b, (cap, _) in zip(sw.detection.boundaries, THREE_LEVEL):
        assert b.lo <= cap <= b.hi * 1.05


def test_adaptive_bisection_targets_brackets_only():
    """Refinement rounds must spend samples near boundaries, not mid-plateau:
    every post-coarse size lies inside a round's recorded bracket."""
    r = SyntheticRunner(TWO_LEVEL, noise=0.0)
    sw = adaptive_sweep("load_sum", runner=r, lo=16 * 2**10, hi=64 * 2**20,
                        resolution=0.10, coarse_per_decade=3)
    coarse = sw.history[0]["new_points"]
    refinements = sw.sizes_run = r.sizes_run[coarse:]
    all_brackets = [b for h in sw.history for b in h["brackets"]]
    for s in refinements:
        assert any(lo < s < hi for lo, hi in all_brackets), (s, all_brackets)


def test_adaptive_single_level_converges_round_one():
    r = SyntheticRunner([(None, 30.0)])
    sw = adaptive_sweep("load_sum", runner=r, lo=16 * 2**10, hi=16 * 2**20,
                        coarse_per_decade=3)
    assert sw.rounds == 1 and sw.converged
    assert sw.detection.n_levels == 1


def test_adaptive_resolution_floor_terminates():
    """A bracket narrower than one working-set tile can't refine further —
    the driver must flag it floored and stop, not loop to max_rounds."""
    r = SyntheticRunner([(12 * 2**10, 90.0), (None, 20.0)], noise=0.0)
    sw = adaptive_sweep("load_sum", runner=r, lo=8 * 2**10, hi=256 * 2**10,
                        resolution=0.001, coarse_per_decade=8, max_rounds=12)
    assert sw.rounds < 12
    assert sw.converged


# ---------------------------------------------------------------------------
# fit + serialization + registry + report
# ---------------------------------------------------------------------------

def _fitted(levels=THREE_LEVEL, **kw):
    return characterize(runner=SyntheticRunner(levels), register=False,
                        prior=HardwareSpec("prior", None, (
                            MemLevel("L1d", 32 * 2**10, None),
                            MemLevel("DRAM", None, None))),
                        lo=16 * 2**10, hi=64 * 2**20, **kw)


def test_characterize_pipeline_fits_all_mixes_every_level():
    model, sweep = _fitted()
    assert model.schema_version == 3
    assert len(model.levels) == 3
    for lvl in model.levels:
        assert set(lvl.bandwidth) == {"load_sum", "copy", "fma_8", "fma_32"}
        rels = model.mix_penalty[lvl.name]
        assert max(rels.values()) == pytest.approx(1.0)
        # penalties recovered within tolerance
        assert rels["fma_32"] == pytest.approx(0.4, abs=0.1)
    # detected capacities match ground truth
    for lvl, (cap, gbps) in zip(model.levels, THREE_LEVEL):
        if cap:
            assert abs(math.log(lvl.capacity_bytes / cap)) < math.log(1.5)
        assert lvl.bandwidth["load_sum"]["gbps"] == pytest.approx(gbps,
                                                                  rel=0.15)
    # provenance records the sweep economics
    assert model.provenance["sweep"]["n_points"] < \
        model.provenance["sweep"]["dense_equivalent"]
    # sysfs prior cross-check: the 32K prior is inside a measured bracket
    checks = {c["prior"]: c for c in model.sysfs_prior["checks"]}
    assert checks["L1d"]["within_bracket"]


def test_fitted_model_json_roundtrip(tmp_path):
    model, _ = _fitted()
    p = tmp_path / "fitted.json"
    model.to_json(p)
    back = FittedMachineModel.from_json(p)
    assert back.schema_version == model.schema_version
    assert back.levels == model.levels
    assert back.to_dict() == model.to_dict()
    d = json.loads(p.read_text())
    d["schema_version"] = 99
    with pytest.raises(ValueError, match="newer"):
        FittedMachineModel.from_dict(d)


def test_fitted_model_registers_and_compares():
    model, _ = _fitted()
    model.name = "synthetic-3level"
    spec = model.register()
    assert "synthetic-3level" in available_specs()
    assert get_spec("synthetic-3level") is spec
    assert spec.levels[0].size_bytes == model.levels[0].capacity_bytes
    assert spec.peak_flops is None      # measured model: FLOP peak unknown

    cmp = model.compare_to(A64FX)
    assert cmp["n_detected"] == 3 and cmp["n_documented"] == 3
    l1 = cmp["levels"][0]
    assert l1["documented"] == "L1d"
    assert l1["capacity_ratio"] == pytest.approx(
        model.levels[0].capacity_bytes / (64 * 2**10))
    assert "bw_ratio" in l1


def test_to_machine_model_downgrade_and_report():
    model, sweep = _fitted()
    legacy = model.to_machine_model()
    assert isinstance(legacy, MachineModel)
    assert set(legacy.level_bw) == {l.name for l in model.levels}
    for lvl, mixes in legacy.mix_penalty.items():
        assert max(mixes.values()) == pytest.approx(1.0)
    md = render_markdown(model, sweep, documented=ALTRA)
    for needle in ("Detected hierarchy", "Sweep economics",
                   "sysfs prior cross-check", "Table-1 deltas", model.name):
        assert needle in md


def test_probe_sizes_one_per_level_inside_band():
    r = SyntheticRunner(THREE_LEVEL)
    sw = adaptive_sweep("load_sum", runner=r, lo=16 * 2**10, hi=64 * 2**20)
    probes = probe_sizes(sw.detection)
    assert len(probes) == 3
    measured = {p.nbytes for p in sw.result.points}
    assert set(probes) <= measured      # re-times, never new compilations


def test_fit_keeps_detection_bandwidth_when_band_empty():
    """Detected capacity below 2x the grid floor: summarize's band for that
    level is empty — the detection plateau stats must survive as the
    level's primary-mix cell instead of an empty bandwidth dict, and
    probe_sizes must not burn samples on sizes no band will credit."""
    r = SyntheticRunner([(28 * 2**10, 100.0), (None, 10.0)], noise=0.0)
    model, sweep = characterize(
        mixes=("load_sum", "copy"), runner=r, register=False,
        prior=HardwareSpec("p", None, (MemLevel("DRAM", None, None),)),
        lo=16 * 2**10, hi=16 * 2**20)
    assert len(model.levels) == 2
    l1 = model.levels[0]
    assert l1.capacity_bytes < 2 * 16 * 2**10     # the empty-band regime
    assert l1.bandwidth["load_sum"]["gbps"] == pytest.approx(100.0, rel=0.1)
    assert all(l.bandwidth for l in model.levels)
    probes = probe_sizes(sweep.detection)
    assert probes      # DRAM still probed; L1's band-less probe skipped
    assert all(s > l1.capacity_bytes for s in probes)


def test_adaptive_rejects_degenerate_rounds():
    with pytest.raises(ValueError, match="max_rounds"):
        adaptive_sweep("load_sum", runner=SyntheticRunner(), max_rounds=0)


def test_crosscheck_prior_flags_disagreement():
    sizes, g = sample_curve(TWO_LEVEL, noise=0.01)
    det = detect_levels(sizes, g)
    prior = HardwareSpec("prior", None, (
        MemLevel("L1d", 256 * 2**10, None),     # matches the true boundary
        MemLevel("L2", 16 * 2**20, None),       # fictitious level
        MemLevel("DRAM", None, None)))
    chk = crosscheck_prior(det, prior)
    by = {c["prior"]: c for c in chk["checks"]}
    assert by["L1d"]["within_bracket"]
    assert not by["L2"]["within_bracket"]
    assert by["L2"]["nearest_detected"] is not None


# ---------------------------------------------------------------------------
# satellites: machine_model schema + registry + detect_host hardening
# ---------------------------------------------------------------------------

def test_machine_model_v2_roundtrip_tuples(tmp_path):
    m = MachineModel(hardware={"name": "x",
                               "levels": [("L1", 32768, None),
                                          ("DRAM", None, None)]},
                     level_bw={"L1": {"load_sum": 9.0}},
                     ridge_flops_per_byte=2.0,
                     mix_penalty={"L1": {"load_sum": 1.0}})
    assert m.model_schema_version == MODEL_SCHEMA_VERSION
    p = tmp_path / "m.json"
    m.to_json(p)
    back = MachineModel.from_json(p)
    # THE round-trip fix: levels come back as tuples, object compares equal
    assert back.hardware["levels"] == (("L1", 32768, None),
                                       ("DRAM", None, None))
    assert back == m


def test_machine_model_v1_golden_back_compat():
    back = MachineModel.from_json(DATA / "machine_model_v1.json")
    assert back.model_schema_version == 1
    assert back.hardware["levels"][0] == ("L1", 32768, None)
    assert back.level_bw["L1"]["load_sum"] == pytest.approx(98.5)
    assert back.ridge_flops_per_byte == 4.0
    with pytest.raises(ValueError, match="newer"):
        MachineModel.from_dict({"hardware": {},
                                "model_schema_version":
                                    MODEL_SCHEMA_VERSION + 1})


def test_peak_flops_none_means_undocumented():
    assert ALTRA.peak_flops is None
    assert THUNDERX2.peak_flops is None
    assert A64FX.peak_flops == pytest.approx(3.072e12)
    assert detect_host().peak_flops is None


def test_spec_registry():
    for name in ("tpu-v5e", "fujitsu-a64fx", "ampere-altra-q80-30",
                 "marvell-thunderx2"):
        assert name in available_specs()
    assert get_spec("tpu-v5e").peak_flops == 197e12
    assert get_spec("host").levels[-1].name == "DRAM"
    with pytest.raises(KeyError, match="unknown machine spec"):
        get_spec("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_spec(A64FX)


def test_parse_cache_size_suffix_zoo():
    assert parse_cache_size("64K") == 64 * 2**10
    assert parse_cache_size("64k") == 64 * 2**10
    assert parse_cache_size("64KiB") == 64 * 2**10
    assert parse_cache_size("64 kB") == 64 * 2**10
    assert parse_cache_size("8M") == 8 * 2**20
    assert parse_cache_size("1MiB") == 2**20
    assert parse_cache_size("65536") == 65536
    with pytest.raises(ValueError):
        parse_cache_size("64X")
    with pytest.raises(ValueError):
        parse_cache_size("lots")


def _write_cache_index(base, idx, level, typ, size):
    d = base / f"index{idx}"
    d.mkdir(parents=True)
    (d / "level").write_text(level)
    (d / "type").write_text(typ)
    (d / "size").write_text(size)


def test_detect_host_hardened_sysfs(tmp_path):
    base = tmp_path / "cache"
    _write_cache_index(base, 0, "1", "Data", "32KiB")       # KiB suffix
    _write_cache_index(base, 1, "1", "Instruction", "32K")  # skipped
    _write_cache_index(base, 2, "2", "Unified", "1024k")    # lowercase
    _write_cache_index(base, 3, "2", "Unified", "1024K")    # duplicate entry
    _write_cache_index(base, 4, "3", "Unified", "garbage")  # unparseable
    host = detect_host(base)
    names = [(l.name, l.size_bytes) for l in host.levels]
    assert names == [("L1", 32 * 2**10), ("L2", 2**20), ("DRAM", None)]


def test_detect_host_without_sysfs(tmp_path):
    host = detect_host(tmp_path / "nonexistent")
    assert [l.name for l in host.levels] == ["DRAM"]
    assert "sysfs unavailable" in host.notes


# ---------------------------------------------------------------------------
# consumers: autotune + roofline accept fitted models
# ---------------------------------------------------------------------------

def test_autotune_accepts_fitted_model(tmp_path):
    from repro.core.autotune import choose_block_rows, model_block_rows
    model, _ = _fitted()
    # L1 ~= 32K -> blocks of rows*128*4 bytes <= 16K -> 32 rows
    assert model_block_rows(model) == 32
    assert choose_block_rows(2**20, model=model) == 32
    # documented HardwareSpec works the same way
    assert model_block_rows(A64FX) == 64            # 64K L1d -> 32K/512B
    # path flavor
    p = tmp_path / "fitted.json"
    model.to_json(p)
    assert choose_block_rows(2**20, model=str(p)) == 32
    # cache file still wins; default path unchanged
    assert choose_block_rows(2**20) == 128


def test_roofline_accepts_fitted_model():
    import jax
    import jax.numpy as jnp
    from repro.roofline.analyze import analyze, machine_constants

    model, _ = _fitted()
    mc = machine_constants(model)
    assert mc["hbm_bw"] == pytest.approx(model.hbm_bw)
    assert "peak_flops" not in mc       # None = undocumented -> keep default

    mc_doc = machine_constants(A64FX)
    assert mc_doc["peak_flops"] == pytest.approx(3.072e12)
    assert mc_doc["hbm_bw"] == pytest.approx(921.6e9 / 48)
    # registry-name flavor
    assert machine_constants("tpu-v5e")["peak_flops"] == 197e12
    assert machine_constants(None) == {}

    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((128, 128)), jnp.ones((128, 128))).compile()
    out = analyze(compiled, machine=model)
    assert out["machine_model"] == model.name
    assert out["machine_constants"]["hbm_bw"] == pytest.approx(model.hbm_bw)
    assert out["t_memory_s"] == pytest.approx(
        out["hbm_bytes"] / model.hbm_bw)


def test_build_machine_model_legacy_wrapper_parity():
    """core.analysis.build_machine_model (now a characterize wrapper) keeps
    its legacy contract: documented hardware levels verbatim, level_bw from
    band attribution, penalties normalized to best."""
    from repro.bench.result import BenchPoint, BenchResult
    from repro.core import analysis

    hw = HardwareSpec("doc", None, (MemLevel("L1", 64 * 2**10, 1e9),
                                    MemLevel("DRAM", None, None)))
    res = BenchResult()
    for nb, g in ((16 * 2**10, 50.0), (2 * 2**20, 8.0)):
        for m, pen in (("load_sum", 1.0), ("copy", 0.8)):
            res.points.append(BenchPoint(
                nbytes=nb, mix=m, dtype="float32", backend="xla", passes=1,
                streams=1, block_rows=None, reps=2, bytes_per_call=nb,
                flops_per_call=0, mean_s=1e-3, std_s=0, min_s=1e-3,
                gbps=g * pen, gflops=0))
    model = analysis.build_machine_model(res, hw)
    assert model.hardware == {"name": "doc",
                              "levels": (("L1", 64 * 2**10, 1e9),
                                         ("DRAM", None, None))}
    assert model.level_bw["L1"]["load_sum"] == pytest.approx(50.0)
    assert model.mix_penalty["L1"]["copy"] == pytest.approx(0.8)
    assert model.mix_penalty["DRAM"]["load_sum"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# end-to-end on the real xla backend (smoke: tiny grid, 1 round)
# ---------------------------------------------------------------------------

def test_e2e_xla_smoke(tmp_path):
    model, sweep = characterize(
        mixes=("copy", "load_sum"), primary="copy", register=False,
        lo=32 * 2**10, hi=4 * 2**20, coarse_per_decade=2, resolution=0.5,
        max_rounds=1, reps=2, warmup=1, target_bytes=1e7)
    assert sweep.rounds == 1
    assert model.levels, "no levels fitted"
    for lvl in model.levels:
        for cell in lvl.bandwidth.values():
            assert cell["gbps"] > 0
    p = tmp_path / "fitted.json"
    model.to_json(p)
    back = FittedMachineModel.from_json(p)
    assert back.levels == model.levels


def test_cli_characterize_smoke(tmp_path, capsys):
    from repro.bench.cli import main as cli_main
    out = tmp_path / "fitted.json"
    report = tmp_path / "report.md"
    rc = cli_main(["characterize", "--smoke", "--max-rounds", "1",
                   "--resolution", "0.5", "--out", str(out),
                   "--report", str(report), "--compare", "fujitsu-a64fx"])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["schema_version"] == 3
    assert d["levels"], "no detected levels in CLI output"
    assert "provenance" in d and d["provenance"]["backend"] == "xla"
    text = capsys.readouterr().out
    assert "Detected hierarchy" in text
    assert "Table-1 deltas" in text
    assert report.exists()


def test_grid_helpers_shared():
    from repro.core import buffers
    g = buffers.hierarchy_grid()
    assert g[0] >= 8 * 2**10 and g[-1] >= 64 * 2**20
    assert list(g) == sorted(set(g))
    # snapped: every size is a real working-set size (idempotent)
    assert list(g) == buffers.snap_sizes(g)
    assert buffers.hierarchy_grid(quick=True) == buffers.QUICK_SIZES
    # sub-tile requests collapse to one measurement
    assert len(buffers.snap_sizes([4096, 4097, 4100])) == 1
