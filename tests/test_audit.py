"""repro.audit — the static accounting verifier (declared mix formulas vs
element-weighted compiled-HLO traffic) and the ECM-style analytic predictor.

Covers: the registry-wide base-knob audit as a pytest-collected lint (every
mix x backend must reconcile, un-waived), corrupted-formula detection (exit
2 naming the mix/backend/knob triple, at both library and CLI level), the
deviceless golden-fixture path, the pinned DCE regression (pre-fix pallas
copy lowering whose timed loop was empty), the UnknownOpcodeWarning bucket,
property-based audits over random rw_RtoW pairs, ECM bound classification /
validation, and the autotune ECM prefilter selecting the same winner as the
exhaustive timed sweep."""
import dataclasses
import json
import math
import types
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

from repro.audit import (EXIT_OK, EXIT_VIOLATION, audit_case, audit_goldens,
                         audit_hlo, audit_registry, ecm_filter_rows,
                         ecm_predict, expected_counts, lint_mix,
                         predict_block_rows, random_rw_pairs, validate_ecm,
                         waiver_reason, write_goldens)
from repro.audit import verify as audit_verify
from repro.bench.cli import main as bench_main
from repro.bench.mixes import get_mix, mix_names, rw_name
from repro.bench.spec import BenchSpec
from repro.characterize.fit import FittedMachineModel, LevelFit
from repro.istream import ProfileCache
from repro.istream.extract import UnknownOpcodeWarning, extract_profile

HLO_DIR = Path(__file__).parent / "data" / "hlo"
SHAPE = (64, 128)
NBYTES = 64 * 128 * 4
PASSES = 4
BACKENDS = ("xla", "pallas")

#: one compiled-case cache for the whole module — repeated audits of the
#: same (mix, backend, knobs) coordinate re-lower nothing
CACHE = ProfileCache()


@pytest.fixture(scope="module")
def base_report():
    """Full registry x both backends at base knobs — the audit lint."""
    return audit_registry(backends=BACKENDS, knob_grid=[{}], shape=SHAPE,
                          passes=PASSES, cache=CACHE)


# ---------------------------------------------------------------------------
# registry-wide lint: every mix x backend reconciles, checked (not waived)
# ---------------------------------------------------------------------------

ALL_CASES = sorted({(b, m) for b in BACKENDS for m in mix_names(b)})


@pytest.mark.parametrize("backend,mix", ALL_CASES,
                         ids=[f"{b}-{m}" for b, m in ALL_CASES])
def test_registry_base_accounting(base_report, backend, mix):
    cases = [c for c in base_report.cases
             if c.backend == backend and c.mix == mix]
    if not cases:
        pytest.skip(f"{mix} does not support {backend}")
    for c in cases:
        if c.waived:   # only the documented caveats may be waived, loudly
            assert c.waived_reason, f"{c.where()} waived without a reason"
            assert waiver_reason(get_mix(mix), backend, {}), \
                f"{c.where()} waived outside the documented policy"
            continue
        assert c.ok, f"{c.where()}: " + "; ".join(
            f"{k.name}: {k.detail}" for k in c.failures)


def test_sharded_backend_audits_clean():
    """The mesh oracle wraps the xla kernels per shard — its compiled
    traffic must reconcile against the same declared formulas, including
    the smoke grid's unroll axis (the rotating-carry pass loop rides
    through the shard wrapper unchanged)."""
    rep = audit_registry(backends=("sharded",), mixes=("copy",),
                         smoke=True, cache=CACHE)
    assert len(rep.cases) == 3
    for case in rep.cases:
        assert case.backend == "sharded" and case.ok and not case.waived, \
            rep.table()


def test_base_report_clean_and_serializable(base_report, tmp_path):
    assert base_report.ok
    assert base_report.exit_code() == EXIT_OK
    assert not base_report.skipped
    d = base_report.to_dict()
    assert d["schema"] == "repro.audit/v1"
    out = tmp_path / "audit.json"
    base_report.to_json(out)
    back = json.loads(out.read_text())
    assert len(back["cases"]) == len(base_report.cases)
    # the rendered table names every case
    table = base_report.table()
    for c in base_report.cases:
        assert c.where() in table


# ---------------------------------------------------------------------------
# corrupted accounting formulas must fail, naming the offending triple
# ---------------------------------------------------------------------------

def _corrupt(monkeypatch, name, **fields):
    bad = dataclasses.replace(get_mix(name), **fields)
    real = audit_verify.get_mix
    monkeypatch.setattr(audit_verify, "get_mix",
                        lambda n: bad if n == name else real(n))


def test_corrupted_reads_formula_fails(monkeypatch):
    _corrupt(monkeypatch, "copy", reads_per_elem=2.0)
    rep = audit_registry(backends=("xla",), mixes=("copy",), smoke=True,
                         cache=CACHE)
    assert rep.exit_code() == EXIT_VIOLATION
    assert rep.violations
    for case in rep.violations:
        assert case.where().startswith("xla/copy")
        assert any(c.name == "loads" for c in case.failures)


def test_corrupted_flops_formula_fails(monkeypatch):
    _corrupt(monkeypatch, "triad", flops_per_elem=7.0)
    rep = audit_registry(backends=("xla",), mixes=("triad",), smoke=True,
                         cache=CACHE)
    assert rep.exit_code() == EXIT_VIOLATION
    assert any(c.name in ("arith", "lint:triad") for case in rep.violations
               for c in case.failures)


def test_cli_audit_goldens_exit0(capsys):
    assert bench_main(["audit", "--goldens", str(HLO_DIR)]) == EXIT_OK
    assert "0 violations" in capsys.readouterr().out


def test_cli_audit_corrupted_exit2_names_case(monkeypatch, capsys):
    _corrupt(monkeypatch, "copy", writes_per_elem=3.0)
    rc = bench_main(["audit", "--goldens", str(HLO_DIR)])
    captured = capsys.readouterr()
    assert rc == EXIT_VIOLATION
    assert "accounting violation" in captured.err
    assert "copy" in captured.err


def test_cli_audit_json(capsys):
    assert bench_main(["audit", "--goldens", str(HLO_DIR), "--json"]) == 0
    d = json.loads(capsys.readouterr().out)
    assert d["schema"] == "repro.audit/v1"
    assert len(d["cases"]) == 26
    assert d["summary"]["waived"] == 0


# ---------------------------------------------------------------------------
# deviceless golden fixtures
# ---------------------------------------------------------------------------

def test_goldens_manifest_covers_both_backends():
    manifest = json.loads((HLO_DIR / "manifest.json").read_text())
    pairs = {(c["backend"], c["mix"]) for c in manifest["cases"]}
    for mix in ("load_sum", "copy", "triad", "rw_2to1", "fma_8"):
        assert ("xla", mix) in pairs and ("pallas", mix) in pairs


def test_goldens_manifest_covers_carried_unroll():
    """The deviceless CI path pins the rotating-carry lowering: every
    carried-mix family head has unroll-2 and unroll-4 fixtures on both
    backends, each with its own passes (>= 2 trips)."""
    manifest = json.loads((HLO_DIR / "manifest.json").read_text())
    triples = {(c["backend"], c["mix"], c.get("unroll", 1))
               for c in manifest["cases"]}
    for mix in ("copy", "triad", "rw_2to1"):
        for u in (2, 4):
            for backend in BACKENDS:
                assert (backend, mix, u) in triples
    for c in manifest["cases"]:
        if c.get("unroll", 1) > 1:
            assert c["passes"] // c["unroll"] >= 2


def test_goldens_audit_clean():
    rep = audit_goldens(HLO_DIR)
    assert rep.ok and rep.exit_code() == EXIT_OK
    assert len(rep.cases) == 26
    assert not rep.waived


def test_dce_fixture_fails_loudly():
    """Pinned regression: the pre-fix pallas copy lowering (outputs not
    loop-carried) dead-code-eliminates the whole timed sweep — the audit
    must call that out as 'dce', not report tiny-but-plausible traffic."""
    hlo = (HLO_DIR / "dce_pallas_copy.txt").read_text()
    case = audit_hlo(hlo, "copy", "pallas", SHAPE, passes=PASSES)
    assert not case.ok
    names = [c.name for c in case.failures]
    assert "dce" in names
    assert "eliminated" in next(c.detail for c in case.failures
                                if c.name == "dce")


def test_dead_sweep_fixture_fails_loudly():
    """Pinned regression: the pre-fix unroll=4 xla copy lowering, where
    only the LAST unrolled sweep's outputs were loop state — XLA narrowed
    the three interior sweeps to one element each and the trip moved ~1/4
    of the declared traffic.  The audit must fail (exit 2) naming the
    backend/mix[knobs] triple, never waive it."""
    hlo = (HLO_DIR / "dead_sweep_xla_copy_u4.txt").read_text()
    case = audit_hlo(hlo, "copy", "xla", SHAPE, passes=8, unroll=4,
                     knobs={"unroll": 4})
    assert not case.ok and not case.waived
    assert case.where() == "xla/copy[unroll=4]"
    names = {c.name for c in case.failures}
    assert names & {"dce", "loads", "stores"}, names
    rep = audit_verify.AuditReport(cases=[case])
    assert rep.exit_code() == EXIT_VIOLATION
    assert "xla/copy[unroll=4]" in rep.table()


def test_write_goldens_roundtrip(tmp_path):
    manifest = write_goldens(tmp_path, shape=(16, 128), passes=2)
    assert (tmp_path / "manifest.json").exists()
    for case in manifest["cases"]:
        assert (tmp_path / case["file"]).exists()
    rep = audit_goldens(tmp_path)
    assert rep.ok, rep.table()


# ---------------------------------------------------------------------------
# unknown opcodes stay loud (the istream extraction contract audit rides on)
# ---------------------------------------------------------------------------

BOGUS_HLO = """\
HloModule bogus

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  ROOT %weird.1 = f32[64,128]{1,0} frobnicate(%p0)
}
"""


def test_unknown_opcode_warns_and_buckets():
    with pytest.warns(UnknownOpcodeWarning, match="frobnicate"):
        raw = extract_profile(BOGUS_HLO, expected_trips=1)
    assert raw["per_iter"]["unknown"].get("frobnicate") == 64 * 128


# ---------------------------------------------------------------------------
# property: random members of the open-ended rw_RtoW family reconcile
# ---------------------------------------------------------------------------

def test_random_rw_pairs_deterministic():
    assert random_rw_pairs(4, seed=7) == random_rw_pairs(4, seed=7)
    assert all(p.startswith("rw_") for p in random_rw_pairs(4))


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=4))
def test_rw_family_accounting_property(r, w):
    name = rw_name(r, w)
    spec = BenchSpec(mixes=(name,), sizes=(NBYTES,), backend="xla",
                     passes=PASSES, reps=2, warmup=0)
    case = audit_case(spec, name, SHAPE, "float32", PASSES, cache=CACHE)
    assert case.ok, f"{case.where()}: " + "; ".join(
        f"{c.name}: {c.detail}" for c in case.failures)


# ---------------------------------------------------------------------------
# unroll soundness: carried mixes ENFORCED at unroll>1 (waiver retired)
# ---------------------------------------------------------------------------

UNROLL_CASES = [(b, m, u) for b in BACKENDS
                for m in ("copy", "triad", "rw_2to1")
                for u in (2, 4)]


@pytest.mark.parametrize("backend,mix,unroll", UNROLL_CASES,
                         ids=[f"{b}-{m}-u{u}" for b, m, u in UNROLL_CASES])
def test_carried_unroll_enforced_and_scales(backend, mix, unroll):
    """The tentpole acceptance check: carried mixes at unroll>1 carry a
    full compiled-traffic expectation (no waiver) and the rotating-carry
    lowering keeps every sweep live — per-TRIP loads/stores cover u x one
    sweep's declared stream traffic, and the audit passes."""
    from repro.istream.analyze import analyze_case
    assert waiver_reason(get_mix(mix), backend, {"unroll": unroll}) is None
    p = max(PASSES, 2 * unroll)
    spec = BenchSpec(mixes=(mix,), sizes=(NBYTES,), backend=backend,
                     passes=p, unroll=unroll, reps=2, warmup=0)
    case = audit_case(spec, mix, SHAPE, "float32", p, cache=CACHE)
    assert not case.waived
    assert case.ok, f"{case.where()}: " + "; ".join(
        f"{c.name}: {c.detail}" for c in case.failures)
    prof = analyze_case(spec, mix, SHAPE, "float32", p, cache=CACHE)
    m = get_mix(mix)
    n = SHAPE[0] * SHAPE[1]
    tol = unroll * (64 + 0.03 * n)
    assert prof.per_iter["loads"] >= unroll * m.reads_per_elem * n - tol
    assert prof.per_iter["stores"] >= unroll * m.writes_per_elem * n - tol


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=1, max_value=3),
       st.sampled_from([2, 4]))
def test_rw_unroll_linear_scaling_property(r, w, u):
    """Property over the open-ended rw_RtoW family: on xla the compiled
    per-trip loads/stores at unroll=u are ~u x the unroll=1 counts (the
    pre-fix lowering scaled them by ~1, not u)."""
    from repro.istream.analyze import analyze_case
    name = rw_name(r, w)
    base = analyze_case(
        BenchSpec(mixes=(name,), sizes=(NBYTES,), backend="xla",
                  passes=PASSES, reps=2, warmup=0),
        name, SHAPE, "float32", PASSES, cache=CACHE)
    p = max(PASSES, 2 * u)
    prof = analyze_case(
        BenchSpec(mixes=(name,), sizes=(NBYTES,), backend="xla",
                  passes=p, unroll=u, reps=2, warmup=0),
        name, SHAPE, "float32", p, cache=CACHE)
    for key in ("loads", "stores"):
        exp = u * base.per_iter[key]
        assert abs(prof.per_iter[key] - exp) <= u * 64 + 0.03 * exp, \
            (name, key, prof.per_iter[key], exp)


def test_scalar_unroll_was_never_exempt():
    """Regression pin for the over-broad waiver condition (it swept
    scalar-accumulator mixes on pallas into the carried-mix waiver):
    scalar mixes at unroll>1 carry a full expectation on both backends."""
    for backend in BACKENDS:
        for name in ("load_sum", "fma_8"):
            for u in (2, 4):
                assert waiver_reason(get_mix(name), backend,
                                     {"unroll": u}) is None
                assert expected_counts(get_mix(name), backend, 8192.0,
                                       {"unroll": u}) is not None


def test_smoke_grid_covers_unroll_axis():
    """The CI fast-fail gate audits the unroll AND load axes, not just base
    knobs."""
    from repro.audit.verify import default_knob_grid
    assert default_knob_grid(smoke=True) == [{}, {"unroll": 2},
                                             {"unroll": 4}, {"load": 1}]


# ---------------------------------------------------------------------------
# waiver policy: documented, named, never a silent pass
# ---------------------------------------------------------------------------


def test_waiver_reason_base_knobs_none():
    for backend in BACKENDS:
        for name in ("copy", "triad", "rw_2to1", "fma_8"):
            assert waiver_reason(get_mix(name), backend, {}) is None


def test_expected_counts_derive_from_declared_fields():
    """The whole corruption-detection mechanism: expectations come from the
    DECLARED registry fields, so editing a formula moves the expectation
    away from the (unchanged) compiled traffic."""
    good = expected_counts(get_mix("copy"), "xla", 8192)
    bad = expected_counts(dataclasses.replace(get_mix("copy"),
                                              reads_per_elem=2.0),
                          "xla", 8192)
    assert bad["loads"] == 2 * good["loads"]


def test_lint_mix_flags_inconsistent_rw():
    bad = dataclasses.replace(get_mix("rw_2to1"), flops_per_elem=999.0)
    assert any(not ok for _, ok, _ in lint_mix(bad))
    assert all(ok for _, ok, _ in lint_mix(get_mix("rw_2to1")))


# ---------------------------------------------------------------------------
# ECM analytic predictor
# ---------------------------------------------------------------------------

def _model(rate=1e9, l1_gbps=100.0, dram_gbps=10.0, l1_cap=100_000):
    return FittedMachineModel(
        name="synthetic",
        levels=(LevelFit(name="L1", capacity_bytes=l1_cap, capacity_ci=None,
                         bandwidth={"load_sum": {"gbps": l1_gbps, "ci": None,
                                                 "n": 1}}),
                LevelFit(name="DRAM", capacity_bytes=None, capacity_ci=None,
                         bandwidth={"load_sum": {"gbps": dram_gbps,
                                                 "ci": None, "n": 1}})),
        issue={"rate_elems_per_s": rate})


def _profile(loads=8192.0, stores=0.0, arith=8192.0, move=0.0,
             mix="load_sum", nbytes=NBYTES):
    from repro.istream.analyze import InstructionProfile
    return InstructionProfile(mix=mix, backend="xla", shape=SHAPE,
                              dtype="float32", nbytes=nbytes, unroll=1,
                              interleave=1,
                              per_iter={"loads": loads, "stores": stores,
                                        "arith": arith, "move": move},
                              critical_path=1.0, trips=PASSES, passes=PASSES,
                              loop="while.1")


def test_ecm_core_vs_data_bound():
    prof = _profile()
    slow_core = ecm_predict(prof, _model(rate=1e9))
    assert slow_core.bound == "core"
    assert slow_core.t_pred_s == pytest.approx(16384 / 1e9)
    fast_core = ecm_predict(prof, _model(rate=1e13))
    assert fast_core.bound == "data"
    # fits L1 (32 KiB < 100 KB): only the L1 term on the transfer path
    assert list(fast_core.level_times) == ["L1"]
    assert fast_core.t_pred_s == pytest.approx(32768 / 100e9)
    assert fast_core.gbps == pytest.approx(
        fast_core.declared_bytes / fast_core.t_pred_s / 1e9)


def test_ecm_level_path_extends_past_capacity():
    big = _profile(loads=65536.0, arith=65536.0, nbytes=262144)
    pred = ecm_predict(big, _model(rate=1e13))
    assert set(pred.level_times) == {"L1", "DRAM"}


def test_validate_ecm_zero_error_on_self():
    model = _model(rate=1e9)
    prof = _profile()
    pred_call_s = ecm_predict(prof, model).t_pred_s * PASSES
    point = types.SimpleNamespace(mix="load_sum", backend="xla",
                                  nbytes=NBYTES, passes=PASSES,
                                  mean_s=pred_call_s, unroll=1,
                                  block_rows=None,
                                  gbps=4 * NBYTES / pred_call_s / 1e9)
    out = validate_ecm([(point, prof)], model)
    assert out["n"] == 1
    assert out["median_abs_rel_err"] == pytest.approx(0.0, abs=1e-12)
    assert out["rows"][0]["bound"] == "core"


def test_validate_ecm_skips_unmeasured():
    model = _model()
    point = types.SimpleNamespace(mix="load_sum", backend="xla",
                                  nbytes=NBYTES, passes=PASSES, mean_s=0.0,
                                  unroll=1, gbps=0.0)
    out = validate_ecm([(point, None), (point, _profile())], model)
    assert out["n"] == 0 and out["median_abs_rel_err"] is None


# ---------------------------------------------------------------------------
# block-shape prefilter: same winner as the exhaustive timed sweep
# ---------------------------------------------------------------------------

class _FakeRunner:
    """Deterministic 'timing': throughput peaked at block_rows=64."""

    def __init__(self):
        self.timed_rows = []

    def run(self, spec):
        rows = spec.block_rows or 128
        self.timed_rows.append(rows)
        gbps = 100.0 - abs(math.log2(rows) - 6.0) * 10.0
        return types.SimpleNamespace(
            points=[types.SimpleNamespace(gbps=gbps)])


def test_prefilter_ranking_prefers_fewer_blocks_in_core_regime():
    pred = predict_block_rows(NBYTES, _model(rate=1e9), (8, 16, 32, 64))
    assert pred[64] > pred[32] > pred[16] > pred[8]
    kept, _ = ecm_filter_rows(NBYTES, _model(rate=1e9), (8, 16, 32, 64),
                              keep=2)
    assert kept == (32, 64)


def test_autotune_ecm_prefilter_matches_exhaustive():
    from repro.core.autotune import sweep_block_shapes
    model = _model(rate=1e9)
    exhaustive = sweep_block_shapes(NBYTES, runner=_FakeRunner())
    pruned_runner = _FakeRunner()
    pruned = sweep_block_shapes(NBYTES, model=model, ecm_keep=3,
                                runner=pruned_runner)
    assert pruned.best_rows == exhaustive.best_rows == 64
    assert pruned.ecm is not None
    assert set(pruned.ecm["kept"]) == set(pruned_runner.timed_rows)
    assert pruned.ecm["pruned"]      # the saving is recorded, not silent
    assert len(pruned_runner.timed_rows) < len(exhaustive.table)
    for rows in pruned.ecm["pruned"]:
        assert rows not in pruned_runner.timed_rows
        assert rows in pruned.ecm["predicted_gbps"]


# ---------------------------------------------------------------------------
# autotune unroll objective: ranks audited GB/s, immune to phantom traffic
# ---------------------------------------------------------------------------

class _UnrollRunner:
    """Injected timing for the unroll leg: a machine where unroll does not
    help (mild decode penalty, GB/s slightly decreasing in u).
    ``phantom=True`` reproduces the pre-fix measurement shape — only ~1/u
    of the declared traffic executed, so the declared-bytes normalization
    reported ~u x the true GB/s."""

    def __init__(self, phantom: bool = False):
        self.phantom = phantom

    def run(self, spec):
        u = spec.unroll or 1
        gbps = 100.0 / (1.0 + 0.02 * (u - 1))
        if self.phantom and u > 1:
            gbps *= u
        return types.SimpleNamespace(
            points=[types.SimpleNamespace(gbps=gbps)])


def test_autotune_unroll_objective_sound_not_phantom():
    """Regression for the tuner leg of the dead-sweep bug: with sound
    measurements the objective picks the genuinely best unroll, while
    pre-fix-shaped throughput (x u phantom) would flip the winner to the
    largest candidate."""
    from repro.core.autotune import CANDIDATE_UNROLLS, sweep_block_shapes
    sound = sweep_block_shapes(NBYTES, mix="copy", tune_unroll=True,
                               runner=_UnrollRunner())
    assert sound.best_unroll == 1
    assert sound.unroll_audit == {u: None for u in CANDIDATE_UNROLLS}
    phantom = sweep_block_shapes(NBYTES, mix="copy", tune_unroll=True,
                                 runner=_UnrollRunner(phantom=True))
    assert phantom.best_unroll == max(CANDIDATE_UNROLLS)
    assert phantom.best_unroll != sound.best_unroll


def test_autotune_unroll_objective_excludes_waived(monkeypatch):
    """A candidate whose (mix, unroll) combination carries an accounting
    waiver is timed and reported but never wins — even when its un-audited
    GB/s looks best (the pre-fix phantom shape)."""
    from repro.core.autotune import sweep_block_shapes
    monkeypatch.setattr(
        audit_verify, "waiver_reason",
        lambda mix, backend, knobs=None:
        "carried-mix unroll (simulated)"
        if (knobs or {}).get("unroll", 1) > 1 else None)
    r = sweep_block_shapes(NBYTES, mix="copy", tune_unroll=True,
                           runner=_UnrollRunner(phantom=True))
    assert r.best_unroll == 1
    assert all(r.unroll_audit[u] for u in r.unroll_audit if u > 1)
