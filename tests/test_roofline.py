"""Roofline analyzer: HLO collective parsing, ring model, end-to-end analyze."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analyze import (CollectiveOp, RooflineTerms, _shape_bytes,
                                    analyze, parse_collectives)

SAMPLE_HLO = """
ENTRY %main {
  %ar = f32[512,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag.1 = bf16[64,256]{1,0} all-gather(%y), replica_groups=[8,2]<=[16], dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %a2a = (f32[32,32]{1,0}, f32[32,32]{1,0}) all-to-all(%p, %q), replica_groups={{0,1,2,3}}
  %cp = u32[16]{0} collective-permute(%r), source_target_pairs={{0,1},{1,0}}
  %done = f32[512,1024]{1,0} all-reduce-done(%ar2)
  %notacoll = f32[8,8]{1,0} add(%a, %b)
}
"""


def test_parse_collectives():
    ops = parse_collectives(SAMPLE_HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.bytes == 512 * 1024 * 4
    assert ar.group_size == 4
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.bytes == 64 * 256 * 2
    assert ag.group_size == 2                 # v2 format [8,2]
    a2a = next(o for o in ops if o.kind == "all-to-all")
    assert a2a.bytes == 2 * 32 * 32 * 4       # tuple shape: both operands


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[4,4], bf16[8])") == 4 * 4 * 4 + 8 * 2
    assert _shape_bytes("pred[16]") == 16


def test_ring_model():
    t = RooflineTerms(flops=0, hbm_bytes=0, collectives=[
        CollectiveOp("all-reduce", 1000_000_000, 4)])
    # 2*(n-1)/n * bytes / 50e9 = 1.5e9/50e9
    assert t.t_collective == pytest.approx(2 * 3 / 4 * 1e9 / 50e9)
    t2 = RooflineTerms(flops=197e12, hbm_bytes=819e9, collectives=[])
    assert t2.t_compute == pytest.approx(1.0)
    assert t2.t_memory == pytest.approx(1.0)
    assert t2.t_collective == 0.0


def test_dominant_term():
    t = RooflineTerms(flops=197e12, hbm_bytes=1, collectives=[])
    assert t.dominant == "compute"
    t = RooflineTerms(flops=1, hbm_bytes=819e9 * 10, collectives=[])
    assert t.dominant == "memory"


def test_analyze_end_to_end():
    f = jax.jit(lambda a, b: (a @ b).sum())
    c = f.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    rec = analyze(c, model_flops=2 * 256**3)
    assert rec["flops"] > 0
    assert rec["t_compute_s"] > 0
    assert 0 < rec["useful_flop_ratio"] <= 1.5
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["peak_device_bytes"] > 0
