"""Lightweight fallback for ``hypothesis`` when it is not installed.

Implements just the surface these tests use — ``given``, ``settings`` and the
``floats`` / ``integers`` / ``sampled_from`` strategies — as a deterministic
example generator: boundary values first, then seeded-random draws.  Install
the real thing for actual property-based shrinking:

    pip install -e .[test]     # see pyproject.toml [test] extra
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, edges, gen):
        self.edges = list(edges)   # deterministic boundary examples
        self.gen = gen             # rng -> random example

    def draw(self, rng, i):
        if i < len(self.edges):
            return self.edges[i]
        return self.gen(rng)


def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
           allow_infinity=False, **_):
    lo, hi = float(min_value), float(max_value)
    mid = lo + (hi - lo) / 2.0
    return _Strategy([lo, hi, mid], lambda rng: rng.uniform(lo, hi))


def integers(min_value=0, max_value=2**31 - 1, **_):
    lo, hi = int(min_value), int(max_value)
    return _Strategy([lo, hi], lambda rng: rng.randint(lo, hi))


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(elems, lambda rng: rng.choice(elems))


st = types.SimpleNamespace(floats=floats, integers=integers,
                           sampled_from=sampled_from)


class settings:
    """Decorator: records max_examples on the (possibly given-wrapped) fn."""

    def __init__(self, max_examples=10, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 10))
            rng = random.Random(0)
            for i in range(n):
                vals = [s.draw(rng, i) for s in strategies]
                fn(*args, *vals, **kwargs)
        # hide the strategy-filled (rightmost) params from pytest, which
        # would otherwise try to resolve them as fixtures
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strategies)])
        return wrapper
    return deco
