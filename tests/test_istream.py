"""repro.istream — HLO parser on synthetic modules (fusion inlining, while
weighting, trip-count fallback, critical path), real compiled-case
extraction (trips track passes; unroll halves trips), the passes-free
ProfileCache, the OSACA-style bound pair, the classifier (synthetic census
+ fitted-model path), the fitted-model issue field (schema v2), and the
CLI surface."""
import dataclasses
import json
from pathlib import Path

import pytest

import jax
import jax.numpy as jnp

from repro.bench import BenchSpec, Runner
from repro.bench.result import BenchPoint, BenchResult
from repro.istream import (InstructionProfile, ProfileCache, analyze_case,
                           bounds, extract_profile, fit_issue_rate,
                           parse_hlo, run_istream, synthetic_check)
from repro.istream.classify import (BANDWIDTH_BOUND, ISSUE_BOUND,
                                    classify_points, render_fig6)
from repro.istream.extract import (computation_counts, critical_path,
                                   find_pass_loop)

# ---------------------------------------------------------------------------
# synthetic HLO: a counted while whose body calls a fusion — every parser
# feature in ~30 lines (trip count comes from the condition constant, NOT
# a known_trip_count stamp)
# ---------------------------------------------------------------------------

SYNTH = """\
HloModule synth

%fused_add (p0: f32[64,128], p1: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %p1 = f32[64,128]{1,0} parameter(1)
  ROOT %add.1 = f32[64,128]{1,0} add(%p0, %p1)
}

%body (arg: (f32[64,128], s32[])) -> (f32[64,128], s32[]) {
  %arg = (f32[64,128]{1,0}, s32[]) parameter(0)
  %gx = f32[64,128]{1,0} get-tuple-element(%arg), index=0
  %iv = s32[] get-tuple-element(%arg), index=1
  %fus = f32[64,128]{1,0} fusion(%gx, %gx), kind=kLoop, calls=%fused_add
  %one = s32[] constant(1)
  %ivp = s32[] add(%iv, %one)
  ROOT %t = (f32[64,128]{1,0}, s32[]) tuple(%fus, %ivp)
}

%cond (arg: (f32[64,128], s32[])) -> pred[] {
  %arg = (f32[64,128]{1,0}, s32[]) parameter(0)
  %iv.1 = s32[] get-tuple-element(%arg), index=1
  %limit = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv.1, %limit), direction=LT
}

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128]{1,0} parameter(0)
  %c = s32[] constant(0)
  %init = (f32[64,128]{1,0}, s32[]) tuple(%x, %c)
  %w = (f32[64,128]{1,0}, s32[]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=0
}
"""


def test_parse_hlo_structure():
    mod = parse_hlo(SYNTH)
    assert mod.entry == "main"
    assert set(mod.computations) == {"fused_add", "body", "cond", "main"}
    body = mod.computation("body")
    assert body.root == "t"
    fus = body.instrs["fus"]
    assert fus.opcode == "fusion" and fus.attrs["calls"] == "fused_add"
    assert fus.operands == ("gx", "gx") and fus.elems == 64 * 128
    w = mod.computation("main").instrs["w"]
    assert w.opcode == "while"
    assert w.attrs["body"] == "body" and w.attrs["condition"] == "cond"
    assert w.elems == 0                     # tuple-typed result
    assert mod.computation("cond").instrs["lt"].elems == 1


def test_counts_inline_fusion_and_weight_while():
    mod = parse_hlo(SYNTH)
    from repro.istream.extract import _attach_literals
    _attach_literals(mod, SYNTH)
    n = 64 * 128
    body = computation_counts(mod, "body")
    # fusion inlined: the add reads both parameter operands and its root
    # materializes; the scalar iv bump adds 1 arith, the tuple root skips
    # the fusion (control) and the scalar
    assert body.loads == 2 * n
    assert body.arith == n + 1
    assert body.stores == n
    # entry weights body+cond by the condition-constant trip count (5)
    main = computation_counts(mod, "main")
    assert main.loads == 5 * 2 * n


def test_critical_path_and_pass_loop():
    mod = parse_hlo(SYNTH)
    from repro.istream.extract import _attach_literals
    _attach_literals(mod, SYNTH)
    assert critical_path(mod, "fused_add") == 1.0
    assert critical_path(mod, "body") == 1.0     # fusion lat = callee cp
    loop = find_pass_loop(mod, expected_trips=5)
    assert loop is not None and loop.name == "w"
    prof = extract_profile(SYNTH, expected_trips=5)
    assert prof["trips"] == 5 and prof["loop"] == "w"
    assert prof["per_iter"]["loads"] == 2 * 64 * 128


def test_known_trip_count_attr_wins():
    stamped = SYNTH.replace(
        "while(%init), condition=%cond, body=%body",
        "while(%init), condition=%cond, body=%body, "
        'backend_config={"known_trip_count":{"n":"7"}}')
    assert extract_profile(stamped)["trips"] == 7


def test_reduce_latency_is_log_tree():
    hlo = """\
HloModule r

%scalar_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[1024,128]) -> f32[] {
  %x = f32[1024,128]{1,0} parameter(0)
  %z = f32[] constant(0)
  ROOT %r = f32[] reduce(%x, %z), dimensions={0,1}, to_apply=%scalar_add
}
"""
    mod = parse_hlo(hlo)
    n = 1024 * 128
    # log2(131072) = 17 — tree depth, not element count
    assert critical_path(mod, "main") == 17.0
    counts = computation_counts(mod, "main")
    assert counts.arith == n                # reduce consumes operand elems


# ---------------------------------------------------------------------------
# real compiled cases: trips track passes, unroll packs the body
# ---------------------------------------------------------------------------

def _lower(fn, shape=(64, 128)):
    sds = jax.ShapeDtypeStruct(shape, jnp.float32)
    return jax.jit(fn).lower(sds).compile().as_text()


def test_real_extraction_trips_and_unroll():
    import functools
    from repro.core import instruction_mix as im
    p1 = extract_profile(
        _lower(functools.partial(im.k_load_sum, passes=8, unroll=1)),
        expected_trips=8)
    assert p1["trips"] == 8 and p1["loop"] is not None
    assert p1["per_iter"]["loads"] > 0 and p1["critical_path"] > 0
    # unroll=2: half the trips, more work per iteration
    p2 = extract_profile(
        _lower(functools.partial(im.k_load_sum, passes=8, unroll=2)),
        expected_trips=4)
    assert p2["trips"] == 4
    assert p2["per_iter"]["loads"] > p1["per_iter"]["loads"]


def test_analyze_case_profile_cache():
    spec = BenchSpec(mixes=("copy",), sizes=(16 * 2**10,), passes=4,
                     reps=2, warmup=1)
    cache = ProfileCache()
    prof = analyze_case(spec, "copy", (32, 128), "float32", 4, cache=cache)
    assert isinstance(prof, InstructionProfile)
    assert prof.nbytes == 32 * 128 * 4 and prof.trips == 4
    assert cache.misses == 1 and cache.hits == 0
    again = analyze_case(spec, "copy", (32, 128), "float32", 4, cache=cache)
    assert cache.hits == 1 and again == prof
    # different passes: cache still hits (per-iter profile is trip-count
    # free); trips rescale without re-extraction
    p8 = analyze_case(spec, "copy", (32, 128), "float32", 8, cache=cache)
    assert cache.hits == 2 and cache.misses == 1
    assert p8.trips == 8 and p8.per_iter == prof.per_iter
    # a knob change is a different profile (same key discipline as the
    # Runner's case cache)
    analyze_case(spec.replace(unroll=2, passes=None), "copy", (32, 128),
                 "float32", 4, cache=cache)
    assert cache.misses == 2


def test_analyze_case_pallas_backend():
    spec = BenchSpec(mixes=("copy",), sizes=(16 * 2**10,), passes=2,
                     reps=2, warmup=1, backend="pallas")
    prof = analyze_case(spec, "copy", (32, 128), "float32", 2)
    assert prof.backend == "pallas"
    assert prof.issue_elems_per_iter > 0


def test_bounds_pair():
    prof = InstructionProfile(
        mix="copy", backend="xla", shape=(8, 128), dtype="float32",
        nbytes=4096, unroll=1, interleave=1,
        per_iter={"loads": 60.0, "stores": 20.0, "arith": 20.0,
                  "move": 0.0, "ops": 3, "opcodes": {}},
        critical_path=5.0, trips=4, passes=4, loop="w")
    wide = bounds(prof, issue_width=100.0)
    narrow = bounds(prof, issue_width=8.0)
    assert wide["bound"] == "latency" and narrow["bound"] == "throughput"
    assert narrow["throughput_bound"] == pytest.approx(100.0 / 8.0)
    assert wide["latency_bound"] == 5.0


def test_fit_issue_rate_takes_best_point():
    prof = InstructionProfile(
        mix="copy", backend="xla", shape=(8, 128), dtype="float32",
        nbytes=4096, unroll=1, interleave=1,
        per_iter={"loads": 100.0, "stores": 0.0, "arith": 0.0,
                  "move": 0.0, "ops": 1, "opcodes": {}},
        critical_path=1.0, trips=4, passes=4, loop="w")
    mk = lambda s: dataclasses.replace(
        _pt(4096, 1.0, 1.0, "copy"), mean_s=s)
    assert fit_issue_rate([(mk(1e-3), prof), (mk(1e-4), prof),
                           (mk(0.0), prof), (mk(1e-2), None)]) \
        == pytest.approx(400 / 1e-4)


# ---------------------------------------------------------------------------
# classifier
# ---------------------------------------------------------------------------

def _pt(nbytes, bpc, gbps, mix, backend="xla", mean_s=1e-3):
    return BenchPoint(nbytes=nbytes, mix=mix, dtype="float32",
                      backend=backend, passes=4, streams=1, block_rows=None,
                      reps=2, bytes_per_call=bpc, flops_per_call=0.0,
                      mean_s=mean_s, std_s=0.0, min_s=mean_s, gbps=gbps,
                      gflops=0.0)


def test_synthetic_check_sees_both_labels():
    chk = synthetic_check()
    assert chk["ok"], chk
    assert chk["census"] == {BANDWIDTH_BOUND: 1, ISSUE_BOUND: 1}
    assert chk["issue_rate"] > 0


def test_classifier_uses_fitted_model():
    """With a FittedMachineModel the bandwidth comes from the level that
    holds the working set and the issue rate from the schema-v2 issue
    field — no self-calibration."""
    from repro.characterize.fit import FittedMachineModel, LevelFit
    model = FittedMachineModel(
        levels=(LevelFit("L1", 64 * 2**10, None,
                         {"copy": {"gbps": 100.0, "ci": None, "n": 4}}),
                LevelFit("DRAM", None, None,
                         {"copy": {"gbps": 10.0, "ci": None, "n": 4}})),
        issue={"rate_elems_per_s": 1e9})
    prof = InstructionProfile(
        mix="copy", backend="xla", shape=(64, 128), dtype="float32",
        nbytes=32 * 2**10, unroll=1, interleave=1,
        per_iter={"loads": 5e5, "stores": 0.0, "arith": 0.0, "move": 0.0,
                  "ops": 1, "opcodes": {}},
        critical_path=1.0, trips=4, passes=4, loop="w")
    from repro.istream.analyze import profile_join_key
    # 2e6 issue elems @1e9/s = 2ms issue vs 32KiB*4 @100GB/s = 1.3us mem
    res = BenchResult(points=[_pt(32 * 2**10, 4 * 32 * 2**10, 0.1, "copy")])
    out = classify_points(
        res, {profile_join_key("xla", "copy", 1, 1, 32 * 2**10): prof},
        model=model)
    (p,) = out.points
    assert p.istream["label"] == ISSUE_BOUND
    assert p.istream["mem_time_s"] == pytest.approx(
        4 * 32 * 2**10 / 100e9)
    assert out.meta["istream"]["issue_rate_elems_per_s"] == 1e9
    # table renders the classified row
    table = render_fig6(out)
    assert ISSUE_BOUND in table and "| xla | copy |" in table


def test_fitted_model_issue_field_roundtrip():
    """Schema v2: the issue dict survives JSON; v1 files load with None."""
    from repro.characterize.fit import (FITTED_SCHEMA_VERSION,
                                        FittedMachineModel)
    assert FITTED_SCHEMA_VERSION == 3
    m = FittedMachineModel(issue={"rate_elems_per_s": 2.5e12,
                                  "source": "istream"})
    d = json.loads(m.to_json())
    assert d["schema_version"] == 3
    back = FittedMachineModel.from_dict(d)
    assert back.issue == m.issue
    v1 = {k: v for k, v in d.items() if k != "issue"}
    v1["schema_version"] = 1
    old = FittedMachineModel.from_dict(v1)
    assert old.issue is None and old.schema_version == 1


# ---------------------------------------------------------------------------
# the driver + CLI surface
# ---------------------------------------------------------------------------

def test_run_istream_xla_minimal():
    report = run_istream(backends=("xla",), mixes=("copy",),
                         sizes=(16 * 2**10,), unrolls=(1, 2),
                         interleaves=(1,), reps=2)
    pts = report.result.points
    assert len(pts) == 2 and all(p.istream is not None for p in pts)
    assert {p.unroll for p in pts} == {1, 2}
    assert report.issue_rate > 0
    assert len(report.profiles) == 2
    assert "| backend | mix |" in report.table
    # annotated result survives the v6 JSON round-trip
    back = BenchResult.from_dict(json.loads(report.result.to_json()))
    assert back.schema_version == 6
    assert back.points[0].istream["label"] in (BANDWIDTH_BOUND, ISSUE_BOUND)


def test_cli_istream(tmp_path):
    from repro.bench import cli
    out = tmp_path / "ist.json"
    rc = cli.main(["istream", "--backends", "xla", "--mixes", "copy",
                   "--sizes", "16K", "--unrolls", "1,2",
                   "--interleaves", "1", "--reps", "2",
                   "--out", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["schema_version"] == 6
    assert d["points"] and all(p["istream"] is not None
                               for p in d["points"])
    assert d["meta"]["istream"]["issue_rate_elems_per_s"] > 0


def test_cli_istream_rejects_bad_knob():
    from repro.bench import cli
    rc = cli.main(["istream", "--backends", "xla", "--mixes", "fma_8",
                   "--sizes", "16K", "--interleaves", "2"])
    assert rc == 2                          # gate error -> exit code 2


def test_autotune_unroll_objective(tmp_path):
    from repro.core.autotune import (CANDIDATE_UNROLLS, choose_unroll,
                                     sweep_block_shapes)
    r = sweep_block_shapes(16 * 2**10, reps=2, tune_unroll=True)
    assert r.best_unroll in CANDIDATE_UNROLLS
    assert set(r.unroll_table) == set(CANDIDATE_UNROLLS)
    cache = tmp_path / "tune.json"
    cache.write_text(json.dumps({"best_rows": r.best_rows,
                                 "best_unroll": r.best_unroll}))
    assert choose_unroll(cache) == r.best_unroll
    assert choose_unroll(None) == 1
