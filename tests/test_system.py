"""End-to-end system tests: trainer loop with resume, serving loop, and the
multi-device (8 forced host devices) integration paths via subprocess."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_trainer_loss_decreases(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    cfg = reduced(get_arch("granite-3-2b"))
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    tcfg = TrainConfig(steps=12, ckpt_every=6, ckpt_dir=str(tmp_path),
                       log_every=2,
                       opt=adamw.AdamWConfig(lr=2e-3, warmup_steps=2,
                                             total_steps=12))
    tr = Trainer(cfg, (4, 64), mesh, tcfg)
    _, _, hist = tr.train(resume=False)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_trainer_resume_from_checkpoint(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    cfg = reduced(get_arch("granite-3-2b"))
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=16)
    t1 = Trainer(cfg, (4, 64), mesh,
                 TrainConfig(steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                             log_every=4, opt=opt))
    t1.train(resume=False)
    t2 = Trainer(cfg, (4, 64), mesh,
                 TrainConfig(steps=12, ckpt_every=4, ckpt_dir=str(tmp_path),
                             log_every=4, opt=opt))
    _, _, hist = t2.train(resume=True)
    assert hist[0]["step"] >= 8


def test_serve_generates_tokens():
    from repro.configs import get_arch, reduced
    from repro.distributed.sharding import make_smoke_ctx
    from repro.models.common import init_params
    from repro.models.registry import build, init_cache, make_batch
    from repro.models.variant import BASELINE

    ctx = make_smoke_ctx()
    cfg = reduced(get_arch("granite-3-2b"))
    model = build(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    B, prompt_len, gen = 2, 8, 8
    batch = make_batch(cfg, (B, prompt_len), jax.random.key(1))
    cache = init_cache(cfg, B, prompt_len + gen)
    with jax.set_mesh(ctx.mesh):
        dec = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, ctx,
                                                             BASELINE))
        toks = batch["tokens"][:, :1]
        out_tokens = []
        c = cache
        for i in range(prompt_len + gen - 1):
            logits, c = dec(params, c, toks, jnp.int32(i))
            if i < prompt_len - 1:
                toks = batch["tokens"][:, i + 1:i + 2]
            else:
                toks = jnp.argmax(logits[:, :, :cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)
                out_tokens.append(toks)
    assert len(out_tokens) == gen
    for t in out_tokens:
        assert t.shape == (B, 1)
        assert int(t.min()) >= 0 and int(t.max()) < cfg.vocab_size


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_arch, reduced
from repro.distributed.sharding import ShardCtx
from repro.launch.mesh import make_mesh
from repro.models.common import abstract_params, init_params, logical_axes
from repro.models.registry import build, make_batch
from repro.models.variant import BASELINE
from repro.optim import adamw
from repro.train.step import make_train_step

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
ctx = ShardCtx(mesh)
cfg = reduced(get_arch("%ARCH%"))
model = build(cfg)
specs = model.param_specs()
params = init_params(specs, jax.random.key(0))
params = jax.device_put(params, ctx.tree_shardings(abstract_params(specs),
                                                   logical_axes(specs)))
batch = make_batch(cfg, (8, 64), jax.random.key(1))
step = jax.jit(make_train_step(cfg, ctx, opt_cfg=adamw.AdamWConfig(lr=1e-3),
                               variant=BASELINE))
opt = adamw.init_state(params)
with jax.set_mesh(mesh):
    p2, o2, m = step(params, opt, batch)
loss = float(m["loss"])
assert loss == loss and 0 < loss < 20, loss
print("MULTIDEV_OK", loss)
"""


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-236b",
                                  "mamba2-2.7b"])
def test_multidevice_train_step_subprocess(arch):
    """Real 8-device SPMD train step (pod=2, data=2, model=2) incl. MoE EP."""
    code = MULTIDEV_SNIPPET.replace("%ARCH%", arch)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV_OK" in r.stdout


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written on an 8-device mesh restores onto 1 device."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.checkpoint import checkpoint as ckpt
from repro.distributed.sharding import ShardCtx
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh)
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w = jax.device_put(w, ctx.sharding((8, 8), ("batch", "ffn")))
ckpt.save("%DIR%", 3, {"w": w})
print("SAVED")
""".replace("%DIR%", str(tmp_path))
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    # restore in THIS process (1 device)
    from repro.checkpoint import checkpoint as ckpt
    import numpy as np
    restored, manifest = ckpt.restore(tmp_path, {"w": jnp.zeros((8, 8))})
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64, dtype=np.float32).reshape(8, 8))
