"""Synthetic data pipeline: determinism, structure, label alignment."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.data.pipeline import DataConfig, SyntheticTokens, make_pipeline


def test_deterministic_given_step():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    pipe = SyntheticTokens(cfg)
    a = pipe.batch(3)
    b = pipe.batch(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = pipe.batch(4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=2, seed=0)
    b = SyntheticTokens(cfg).batch(0)
    assert b["tokens"].shape == (2, 32)
    assert b["labels"].shape == (2, 32)
    # labels[t] == tokens[t+1] on the overlap
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokens_in_range():
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=2, seed=1)
    b = SyntheticTokens(cfg).batch(0)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 64


def test_zipf_skew():
    """low token ids must be much more frequent than high ids."""
    cfg = DataConfig(vocab_size=1024, seq_len=512, global_batch=8, seed=2)
    t = np.asarray(SyntheticTokens(cfg).batch(0)["tokens"]).ravel()
    low = (t < 16).mean()
    high = (t >= 512).mean()
    assert low > high * 2


def test_encdec_frames():
    cfg = reduced(get_arch("whisper-medium"))
    pipe = make_pipeline(cfg, (2, 16), ctx=None, seed=0)
    b = pipe.batch(0)
    assert b["frames"].shape == (2, cfg.n_audio_ctx, cfg.d_model)
    assert b["frames"].dtype == jnp.bfloat16
