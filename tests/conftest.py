"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device by design;
multi-device tests spawn subprocesses with the flag set explicitly."""
import jax
import pytest


@pytest.fixture(autouse=True)
def _ledger_isolation(tmp_path, monkeypatch):
    """Point the run ledger at a per-test temp dir: in-process CLI tests
    must not append BENCH_history/ records into the repo checkout."""
    from repro.obs import ledger
    monkeypatch.setenv(ledger.LEDGER_ENV, str(tmp_path / "BENCH_history"))


@pytest.fixture(scope="session")
def smoke_ctx():
    from repro.distributed.sharding import make_smoke_ctx
    return make_smoke_ctx()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
