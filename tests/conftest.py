"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device by design;
multi-device tests spawn subprocesses with the flag set explicitly."""
import jax
import pytest


@pytest.fixture(scope="session")
def smoke_ctx():
    from repro.distributed.sharding import make_smoke_ctx
    return make_smoke_ctx()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
