"""The R:W-ratio mix family (store-path attribution): property-based
accounting parity across backends, numerical-correctness oracles for EVERY
registered mix (a mis-ordered load/store fails loudly instead of silently
benchmarking the wrong traffic), the ``summarize(levels=...)`` view, the
golden-file schema round-trips, and deterministic mix listing."""
import json
import math
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.bench import (BenchResult, BenchSpec, BenchSpecError, MAX_RW,
                         RW_RATIOS, Runner, get_backend, get_mix, mix_names,
                         registry, rw_name, rw_ratio)

DATA = Path(__file__).parent / "data"
TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1, passes=1)

#: shared across property examples so repeated (R, W) draws hit the
#: compiled-case cache instead of re-tracing
RUNNER = Runner()


# ---------------------------------------------------------------------------
# the family: one shared accounting formula, open-ended like fma
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=MAX_RW),
       st.integers(min_value=1, max_value=MAX_RW))
def test_rw_accounting_formula(reads, writes):
    """bytes = (R+W) * nbytes, flops = 2(R-1) * n — derived from (R, W) by
    the ONE shared formula, for any family member."""
    m = rw_ratio(reads, writes)
    nbytes, n = 4096, 1024
    assert m.bytes_per_pass(nbytes) == (reads + writes) * nbytes
    assert m.flops_per_pass(n) == 2 * (reads - 1) * n
    assert m.rw == (reads, writes)
    assert get_mix(rw_name(reads, writes)) == m        # open-ended lookup


def test_rw_family_generalizes_copy_and_triad():
    """The formula reproduces the fixed mixes it generalizes."""
    nbytes, n = 65536, 16384
    assert (rw_ratio(1, 1).bytes_per_pass(nbytes)
            == get_mix("copy").bytes_per_pass(nbytes))
    assert (rw_ratio(2, 1).bytes_per_pass(nbytes)
            == get_mix("triad").bytes_per_pass(nbytes))
    assert (rw_ratio(2, 1).flops_per_pass(n)
            == get_mix("triad").flops_per_pass(n))


def test_rw_registry_and_rejects():
    reg = registry()
    for r, w in RW_RATIOS:
        assert rw_name(r, w) in reg
    assert "rw_5to2" not in reg            # canonical ladder only
    assert get_mix("rw_5to2").rw == (5, 2)  # ...but resolvable, like fma_3
    for bad in ("rw_0to1", "rw_1to0", f"rw_{MAX_RW + 1}to1", "rw_zzto1",
                "rw_1to", "rw_", "rw_01to1", "rw_1to02"):
        with pytest.raises(KeyError):
            get_mix(bad)
    with pytest.raises(ValueError):
        rw_ratio(0, 1)
    with pytest.raises(ValueError):
        rw_ratio(1, MAX_RW + 1)


def test_rw_threads_spec_validation():
    """The family flows through BenchSpec validation on every backend; bad
    family parameters surface as BenchSpecError before any timing."""
    for backend in ("xla", "pallas", "sharded"):
        s = BenchSpec(mixes=("rw_3to1",), backend=backend, **TINY)
        assert s.mixes == ("rw_3to1",)
    with pytest.raises(BenchSpecError):
        BenchSpec(mixes=("rw_0to1",), **TINY)
    with pytest.raises(BenchSpecError):
        BenchSpec(mixes=(f"rw_{MAX_RW + 1}to1",), **TINY)
    with pytest.raises(BenchSpecError):    # oracle knob rules still apply
        Runner().run(BenchSpec(mixes=("rw_2to1",), streams=2, **TINY))


# ---------------------------------------------------------------------------
# property-based cross-backend parity (the paper's oracle-vs-embodiment check)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=MAX_RW),
       st.integers(min_value=1, max_value=MAX_RW))
def test_rw_parity_xla_vs_pallas_and_recorded_traffic(reads, writes):
    """For random (R, W), the xla and pallas embodiments report identical
    bytes/flops per call, and the per-point traffic the Runner records at
    devices=1 is exactly formula x passes (registry-derived accounting — the
    numpy-oracle tests below are the kernel-level check that the buffers
    really move that traffic)."""
    name = rw_name(reads, writes)
    acct = {}
    for backend in ("xla", "pallas"):
        spec = BenchSpec(mixes=(name,), backend=backend, **TINY)
        (pt,) = RUNNER.run(spec).points
        assert pt.gbps > 0 and pt.devices == 1, (name, backend)
        assert pt.bytes_per_call == (reads + writes) * pt.nbytes * pt.passes
        assert pt.flops_per_call == (2 * (reads - 1) * (pt.nbytes // 4)
                                     * pt.passes)
        acct[backend] = (pt.bytes_per_call, pt.flops_per_call)
    assert acct["xla"] == acct["pallas"], (name, acct)


def test_rw_parity_sharded_inherits_xla_accounting():
    """The sharded backend runs the xla oracle per shard (PR 2), so the
    family's accounting carries over by construction at devices=1."""
    name = rw_name(2, 1)
    acct = {}
    for backend in ("xla", "sharded"):
        spec = BenchSpec(mixes=(name,), backend=backend, **TINY)
        (pt,) = RUNNER.run(spec).points
        acct[backend] = (pt.bytes_per_call, pt.flops_per_call)
    assert acct["xla"] == acct["sharded"]


# ---------------------------------------------------------------------------
# numerical-correctness oracles: EVERY registered mix vs a numpy reference
# ---------------------------------------------------------------------------

PASSES = 3


def _buffer():
    rng = np.random.default_rng(0)
    x = rng.uniform(0.5, 1.5, size=(32, 128)).astype(np.float32)
    return x.astype(np.float64), jnp.asarray(x)


def _fma_chain(x64, depth):
    v = x64.copy()
    for _ in range(depth):
        v = v * np.float64(np.float32(1.0000001)) + 1e-9
    return v


def _rw_combined(x64, reads):
    from repro.bench.mixes import RW_COMBINE_COEF
    factor = 1.0 + RW_COMBINE_COEF * sum(0.5 ** r for r in range(1, reads))
    return x64 * factor


def _xla_reference(name, x64, p):
    """What the xla oracle kernels compute (perturbation terms are ~1e-30
    relative and vanish in float32)."""
    m = get_mix(name)
    if name == "load_sum":
        return p * x64.sum()
    if name == "copy":
        return p * x64[0, 0] + x64[-1, -1]
    if name == "triad":
        return p * 1.75 * x64[0, 0] + 1.75 * x64[-1, -1]
    if name == "mxu":
        return p * x64[0, 0]
    if m.chase:
        # a full permutation-cycle walk always returns to its start index 0,
        # so the accumulated final-position fold is exactly zero — any other
        # value means the cycle structure (or the walk) is broken
        return 0.0
    if m.fma_depth:
        return p * _fma_chain(x64, m.fma_depth).sum()
    if m.rw is not None:
        v = _rw_combined(x64, m.rw[0])
        return p * v[0, 0] + m.rw[1] * v[-1, -1]
    raise KeyError(name)


def _pallas_reference(name, x64, p, block_rows):
    """What the pallas timed kernels accumulate (block-accumulator grid for
    the load family; array outputs are loop-carried — folded in at their
    first element each pass, plus the final carry's last element, the same
    consumption convention as the xla ``k_copy``/``k_rw`` oracles)."""
    m = get_mix(name)
    lead = x64[::block_rows, 0].sum()          # one lane per visited block
    if name == "load_only":
        return p * lead
    if name == "load_sum":
        return p * x64.sum()
    if name == "copy":
        return p * x64[0, 0] + x64[-1, -1]
    if name == "triad":
        return p * 1.75 * x64[0, 0] + 1.75 * x64[-1, -1]
    if name == "mxu":
        return p * lead                        # blk @ eye accumulates [0, 0]
    if m.chase:
        return 0.0                             # tile-local cycles end at 0
    if m.fma_depth:
        return p * _fma_chain(x64, m.fma_depth).sum()
    if m.rw is not None:
        v = _rw_combined(x64, m.rw[0])
        return p * m.rw[1] * v[0, 0] + m.rw[1] * v[-1, -1]
    raise KeyError(name)


@pytest.mark.parametrize("name", mix_names("xla"))
def test_numeric_parity_xla(name):
    """Each xla kernel's output matches its numpy model — a mis-ordered
    load/store in a future kernel edit fails here, not in a benchmark."""
    x64, x = _buffer()
    spec = BenchSpec(mixes=(name,), backend="xla", sizes=(16 * 2**10,),
                     reps=2, warmup=1, passes=PASSES)
    fn = get_backend("xla").build(spec, get_mix(name), x, PASSES)
    got = float(fn())
    want = _xla_reference(name, x64, PASSES)
    assert got == pytest.approx(want, rel=1e-4), (name, got, want)


@pytest.mark.parametrize("name", mix_names("pallas"))
def test_numeric_parity_pallas(name):
    x64, x = _buffer()
    spec = BenchSpec(mixes=(name,), backend="pallas", block_rows=8,
                     sizes=(16 * 2**10,), reps=2, warmup=1, passes=PASSES)
    fn = get_backend("pallas").build(spec, get_mix(name), x, PASSES)
    got = float(fn())
    want = _pallas_reference(name, x64, PASSES, block_rows=8)
    assert got == pytest.approx(want, rel=1e-4), (name, got, want)


def test_numeric_parity_covers_every_registered_mix():
    """Nothing in the registry escapes the oracle check: every registered mix
    is runnable (and therefore checked above) on xla or pallas."""
    assert set(mix_names()) == set(mix_names("xla")) | set(mix_names("pallas"))


# ---------------------------------------------------------------------------
# summarize(levels=...) — per-level attribution as a result view
# ---------------------------------------------------------------------------

def _mk_result(points):
    from repro.bench.result import BenchPoint
    pts = []
    for mix, nbytes, gbps in points:
        pts.append(BenchPoint(
            nbytes=nbytes, mix=mix, dtype="float32", backend="xla", passes=1,
            streams=1, block_rows=None, reps=1, bytes_per_call=float(nbytes),
            flops_per_call=0.0, mean_s=1e-3, std_s=0.0, min_s=1e-3,
            gbps=gbps, gflops=0.0))
    return BenchResult(points=pts)


def test_summarize_bands_means_and_rel():
    res = _mk_result([("load_sum", 16 * 2**10, 40.0),
                      ("load_sum", 16 * 2**10, 60.0),   # averaged: 50
                      ("copy", 16 * 2**10, 25.0),
                      ("load_sum", 8 * 2**20, 10.0),
                      ("copy", 8 * 2**20, 5.0)])
    levels = (("L1", 64 * 2**10), ("DRAM", None))
    s = res.summarize(levels=levels)
    assert list(s) == ["L1", "DRAM"]
    assert s["L1"]["load_sum"]["gbps"] == pytest.approx(50.0)
    assert s["L1"]["load_sum"]["n"] == 2
    assert s["L1"]["load_sum"]["rel"] == pytest.approx(1.0)
    assert s["L1"]["copy"]["rel"] == pytest.approx(0.5)
    assert s["L1"]["copy"]["band"] == (4096.0, 32768.0)
    assert s["DRAM"]["load_sum"]["gbps"] == pytest.approx(10.0)
    assert s["DRAM"]["copy"]["rel"] == pytest.approx(0.5)
    # unbounded band edge is None (JSON-serializable), NOT float("inf"):
    # a summary stashed into meta must survive to_json as spec-compliant JSON
    assert s["DRAM"]["copy"]["band"][1] is None


def test_summarize_accepts_memlevel_objects_and_default_band():
    from repro.core.machine_model import MemLevel
    res = _mk_result([("copy", 16 * 2**10, 8.0)])
    s = res.summarize(levels=(MemLevel("L1d", 64 * 2**10, None),
                              MemLevel("DRAM", None, None)))
    assert s == res.summarize(levels=(("L1d", 64 * 2**10), ("DRAM", None)))
    # levels=None: one unbounded band
    assert res.summarize()["all"]["copy"]["gbps"] == pytest.approx(8.0)
    # empty bands are omitted, not emitted as {}
    tiny = res.summarize(levels=(("L0", 8 * 2**10),))
    assert tiny == {}


def test_summarize_matches_legacy_attribute_levels():
    """core.analysis.attribute_levels is now a thin view over summarize —
    both derive the identical table."""
    from repro.core import analysis
    from repro.core.machine_model import HardwareSpec, MemLevel
    hw = HardwareSpec(name="t", peak_flops=0.0,
                      levels=(MemLevel("L1", 64 * 2**10, None),
                              MemLevel("DRAM", None, None)))
    res = _mk_result([("load_sum", 16 * 2**10, 40.0),
                      ("copy", 16 * 2**10, 20.0),
                      ("load_sum", 8 * 2**20, 10.0)])
    table = analysis.attribute_levels(res, hw)
    s = res.summarize(levels=hw.levels)
    assert table == {lvl: {m: c["gbps"] for m, c in mixes.items()}
                     for lvl, mixes in s.items()}


# ---------------------------------------------------------------------------
# golden-file round-trips: the back-compat promise, locked in fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname,ver,devices", [
    ("result_v1.json", 1, 1),     # v1: no devices field -> default 1
    ("result_v2.json", 2, 2),
    ("result_v3.json", 3, 4),     # v3: gathered 2-process distributed run
])
def test_golden_result_roundtrip(fname, ver, devices):
    path = DATA / fname
    res = BenchResult.from_json(path)
    assert res.schema_version == ver
    assert res.points and all(p.devices == devices for p in res.points)
    # summarize works on both schema generations
    s = res.summarize(levels=(("L1", 64 * 2**10), ("DRAM", None)))
    assert set(s) == {"L1", "DRAM"}
    for mixes in s.values():
        assert all(c["gbps"] > 0 for c in mixes.values())
    # re-serialization preserves schema_version and round-trips the points
    d = res.to_dict()
    assert d["schema_version"] == ver
    back = BenchResult.from_dict(json.loads(json.dumps(d)))
    assert back.points == res.points
    assert back.spec == res.spec and back.schema_version == ver


def test_golden_v2_points_carry_rw_accounting():
    res = BenchResult.from_json(DATA / "result_v2.json")
    for p in res.points:
        m = get_mix(p.mix)
        assert m.rw is not None
        assert p.bytes_per_call == m.bytes_per_pass(p.nbytes) * p.passes
        assert p.flops_per_call == m.flops_per_pass(p.nbytes // 4) * p.passes


# ---------------------------------------------------------------------------
# deterministic listing + CLI surface
# ---------------------------------------------------------------------------

def test_mix_names_deterministic_order():
    """Families list by their parameter (fma by depth, rw by R:W ratio, then
    name), everything else alphabetically — independent of registration
    order, so CLI list-mixes output is stable."""
    names = mix_names()
    assert names == ["copy", "fma_1", "fma_2", "fma_4", "fma_8", "fma_16",
                     "fma_32", "fma_64", "latency_chase", "load_only",
                     "load_sum", "mxu", "rw_1to2", "rw_1to1", "rw_2to1",
                     "rw_3to1", "rw_4to1", "triad"]
    assert mix_names("pallas") == names
    assert "load_only" not in mix_names("xla")
    assert mix_names("sharded") == mix_names("xla")


def test_cli_run_mix_flag_and_list_mixes_family(tmp_path, capsys):
    from repro.bench import cli
    out = tmp_path / "rw.json"
    rc = cli.main(["run", "--mix", "rw_3to1", "--sizes", "16K", "--reps", "2",
                   "--backend", "xla", "--out", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert [p["mix"] for p in d["points"]] == ["rw_3to1"]
    assert d["points"][0]["bytes_per_call"] == \
        4 * d["points"][0]["nbytes"] * d["points"][0]["passes"]
    assert cli.main(["list-mixes"]) == 0
    cap = capsys.readouterr()
    # the family is listed ratio-ordered, with the open-endedness noted
    assert cap.out.index("rw_1to2") < cap.out.index("rw_1to1") \
        < cap.out.index("rw_2to1") < cap.out.index("rw_4to1")
    assert "rw_RtoW" in cap.out


def test_cli_compare_rw_accounting_agrees(capsys):
    from repro.bench import cli
    rc = cli.main(["compare", "--mix", "rw_2to1", "--sizes", "16K",
                   "--reps", "2"])
    assert rc == 0                      # nonzero would mean a mismatch
    cap = capsys.readouterr()
    assert "rw_2to1" in cap.out and "mismatch" not in cap.out


def test_fig5_quick_sizes_sit_inside_attribution_bands():
    """Quick-mode sizes derive from the detected hierarchy so every point
    attributes to exactly one level — fixed power-of-two sizes would land ON
    band edges (a 32K buffer is outside a 32K L1's (4K, 16K) band)."""
    from benchmarks.fig5_rw_ratio import quick_sizes
    from repro.bench.result import level_band
    from repro.core.machine_model import MemLevel
    levels = (MemLevel("L1", 32 * 2**10, None),
              MemLevel("L2", 256 * 2**10, None),
              MemLevel("L3", 8 * 2**20, None),
              MemLevel("DRAM", None, None))
    sizes = quick_sizes(levels)
    assert len(sizes) == len(levels)
    prev = 2 * 2**10
    for lvl, size in zip(levels, sizes):
        lo, hi = level_band(lvl.size_bytes, prev)
        assert lo < size < hi, (lvl.name, size, lo, hi)
        if lvl.size_bytes:
            prev = lvl.size_bytes
    # cacheless topology still yields a multi-size sweep
    assert len(quick_sizes((MemLevel("DRAM", None, None),))) >= 3
    # a big last-level cache must not push the DRAM size below its band
    # floor (the capped-size regression): 2x the floor is always in-band
    big = (MemLevel("L3", 64 * 2**20, None), MemLevel("DRAM", None, None))
    dram_size = quick_sizes(big)[-1]
    dram_lo, _ = level_band(None, big[0].size_bytes)
    assert dram_size > dram_lo


def test_fig5_smoke_emits_ratio_table(capsys):
    from benchmarks import fig5_rw_ratio
    summary = fig5_rw_ratio.main(smoke=True)
    cap = capsys.readouterr()
    assert "fig5/rw_2to1/" in cap.out
    assert "R:W" in cap.out and "1:1" in cap.out and "3:1" in cap.out
    assert set(summary) == {"all"}
    assert {"rw_1to1", "rw_2to1", "rw_3to1"} <= set(summary["all"])
