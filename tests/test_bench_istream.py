"""The instruction-stream knobs (unroll / interleave) through the bench
stack: spec validation with actionable gate errors, property-based
accounting parity across backends (the PR-3 discipline applied to the new
axes), numeric equality of the interleaved kernel variants against their
plain counterparts, the compiled-case cache-key no-alias guarantee, the
``summarize(key=...)`` grouped view, and the schema-v4 golden round-trip."""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.bench import (BenchResult, BenchSpec, BenchSpecError, Runner,
                         get_backend)
from repro.bench.backends import _NON_CASE_FIELDS, case_knobs
from repro.bench.spec import knob_names

DATA = Path(__file__).parent / "data"
TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1)

#: shared so repeated knob draws hit the compiled-case cache
RUNNER = Runner()


# ---------------------------------------------------------------------------
# spec validation + the improved BenchSpecError surface
# ---------------------------------------------------------------------------

def test_spec_knob_validation():
    s = BenchSpec(unroll=4, interleave=2, passes=8, **TINY)
    assert s.unroll == 4 and s.interleave == 2
    with pytest.raises(BenchSpecError):
        BenchSpec(unroll=0, **TINY)
    with pytest.raises(BenchSpecError):
        BenchSpec(interleave=0, **TINY)
    # explicit passes must divide into whole unrolled bodies
    with pytest.raises(BenchSpecError, match="multiple of unroll"):
        BenchSpec(unroll=3, passes=8, **TINY)
    # auto passes (None) is fine — the Runner rounds up
    BenchSpec(unroll=3, passes=None, **TINY)


def test_unknown_knob_error_lists_valid_fields():
    """from_dict on an unknown field names every valid knob — the error is
    the documentation."""
    d = BenchSpec(**TINY).to_dict()
    d["unrol"] = 2      # typo'd knob
    with pytest.raises(BenchSpecError) as ei:
        BenchSpec.from_dict(d)
    msg = str(ei.value)
    assert "valid fields" in msg
    for name in ("unroll", "interleave", "mixes", "backend"):
        assert name in msg


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_gate_error_names_backend_and_valid_knobs(backend):
    """A knob rejected by a backend gate says WHICH backend's validate
    raised, WHICH rule fired, and lists the valid spec knobs."""
    spec = BenchSpec(mixes=("fma_8",), backend=backend, interleave=2,
                     **TINY)
    with pytest.raises(BenchSpecError) as ei:
        get_backend(backend).validate(spec)
    msg = str(ei.value)
    assert f"{backend}.validate" in msg
    assert "gate:" in msg
    assert "valid spec knobs" in msg
    assert "unroll" in msg and "interleave" in msg


def test_gate_interleave_xor_streams_and_block_rows():
    for kw in (dict(streams=2), dict(block_rows=8)):
        spec = BenchSpec(mixes=("load_sum",), interleave=2, **TINY, **kw)
        with pytest.raises(BenchSpecError, match="gate:"):
            get_backend("xla").validate(spec)


def test_run_mix_rejects_non_interleavable():
    from repro.core.instruction_mix import run_mix
    x = jnp.ones((16, 128), jnp.float32)
    with pytest.raises(KeyError, match="interleav"):
        run_mix("fma_8", x, 1, interleave=2)


# ---------------------------------------------------------------------------
# property-based accounting parity (the PR-3 rw discipline, new axes)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2]),
       st.sampled_from(["copy", "rw_2to1", "load_sum"]))
def test_knob_parity_xla_vs_pallas(unroll, interleave, mix):
    """For any (unroll, interleave, mix) combination both backends report
    IDENTICAL bytes/flops per call, and the recorded traffic is exactly
    formula x passes — the knobs change the instruction stream, never the
    accounting."""
    from repro.bench import get_mix
    acct = {}
    for backend in ("xla", "pallas"):
        spec = BenchSpec(mixes=(mix,), backend=backend, unroll=unroll,
                         interleave=interleave, passes=4, **TINY)
        (pt,) = RUNNER.run(spec).points
        m = get_mix(mix)
        assert pt.gbps > 0, (backend, unroll, interleave, mix)
        assert pt.unroll == unroll and pt.interleave == interleave
        assert pt.bytes_per_call == m.bytes_per_pass(pt.nbytes) * pt.passes
        assert pt.flops_per_call == (m.flops_per_pass(pt.nbytes // 4)
                                     * pt.passes)
        acct[backend] = (pt.bytes_per_call, pt.flops_per_call, pt.passes)
    assert acct["xla"] == acct["pallas"], (mix, unroll, interleave, acct)


def test_passes_round_up_to_unroll():
    """Auto-picked passes round UP to whole unrolled bodies (never down to
    0), and the recorded accounting uses the rounded value."""
    spec = BenchSpec(mixes=("copy",), unroll=3, passes=None,
                     target_bytes=1e5, **TINY)
    (pt,) = RUNNER.run(spec).points
    assert pt.passes % 3 == 0 and pt.passes >= 3


# ---------------------------------------------------------------------------
# numeric equality: interleaved variants compute the same values
# ---------------------------------------------------------------------------

def _buf(rows=32):
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.uniform(0.5, 1.5, (rows, 128)).astype(np.float32))


def test_interleaved_kernels_match_plain():
    from repro.core import instruction_mix as im
    x = _buf()
    np.testing.assert_allclose(
        im.k_load_sum_istream(x, 4, 1, 4), im.k_load_sum(x, 4), rtol=1e-5)
    np.testing.assert_array_equal(
        im.k_copy_istream(x, 4, 1, 2), im.k_copy(x, 4))
    streams = im.rw_streams(x, 2)
    np.testing.assert_allclose(
        im.k_rw_istream(streams, (x,), 2, 1, 2),
        im.k_rw(streams, (x,), 2), rtol=1e-5)


def test_unroll_preserves_values():
    """Scalar-accumulator mixes compute identical values at any unroll.
    Carried mixes differ ONLY by the rotating-carry consumption term —
    the final trip holds u live output slots and each slot's last element
    is folded in, so copy at unroll=u adds exactly (u-1) extra copies of
    the stream's last element versus unroll=1 (the streams themselves are
    unchanged; this pins the consumption convention)."""
    from repro.core import instruction_mix as im
    x = _buf()
    np.testing.assert_allclose(im.k_load_sum(x, 4, unroll=2),
                               im.k_load_sum(x, 4), rtol=1e-5)
    last = float(np.asarray(x)[-1, -1])
    np.testing.assert_allclose(im.k_copy(x, 4, unroll=4),
                               im.k_copy(x, 4) + 3 * last, rtol=1e-5)


# ---------------------------------------------------------------------------
# compiled-case cache key: knob-differing cases never alias
# ---------------------------------------------------------------------------

def test_cache_key_derives_from_full_knob_dict():
    """Forward-compat proof: every BenchSpec field is either explicitly
    excluded as measurement-only or lands in the cache key — a future knob
    that changes compilation can NOT silently alias a stale case."""
    spec = BenchSpec(**TINY)
    knob_cols = {name for name, _ in case_knobs(spec)}
    for f in dataclasses.fields(spec):
        assert (f.name in _NON_CASE_FIELDS) != (f.name in knob_cols), \
            f"field {f.name} neither excluded nor keyed"
    # the new knobs are key columns
    assert {"unroll", "interleave"} <= knob_cols
    # excluded fields are genuinely measurement-only (shape/traffic fields
    # like sizes/dtype appear in the key through other columns)
    assert "reps" in _NON_CASE_FIELDS and "warmup" in _NON_CASE_FIELDS


@pytest.mark.parametrize("knob", [dict(unroll=2), dict(interleave=2)])
def test_cache_no_alias_regression(knob):
    """Two specs differing ONLY in a new knob compile two distinct cases:
    the second run must be a cache MISS, and the two points must differ in
    their recorded knob column."""
    r = Runner()
    base = BenchSpec(mixes=("copy",), passes=4, **TINY)
    r.run(base)
    misses = r.cache_misses
    r.run(base.replace(**knob))
    assert r.cache_misses == misses + 1, f"{knob} aliased a cached case"
    r.run(base.replace(**knob))          # identical knobs re-hit
    assert r.cache_misses == misses + 1


def test_case_keys_distinct_across_knob_grid():
    """Direct key-level check across the whole grid — no two (unroll,
    interleave) combinations share a compiled-case cache key."""
    backend = get_backend("xla")
    from repro.bench import get_mix
    mix = get_mix("copy")
    keys = set()
    for u in (1, 2, 4):
        for i in (1, 2, 4):
            spec = BenchSpec(mixes=("copy",), unroll=u, interleave=i,
                             passes=4, **TINY)
            keys.add(backend.case_key(spec, mix, (32, 128), "float32", 4))
    assert len(keys) == 9


# ---------------------------------------------------------------------------
# summarize grouped by the new axes + schema-v4 golden round-trip
# ---------------------------------------------------------------------------

def test_summarize_key_groups_by_istream_axes():
    specs = [BenchSpec(mixes=("copy",), unroll=u, interleave=i, passes=4,
                       **TINY)
             for u in (1, 2) for i in (1, 2)]
    res = RUNNER.run_many(specs)
    s = res.summarize(key=lambda p: f"{p.mix}/u{p.unroll}x{p.interleave}")
    cells = s["all"]
    assert set(cells) == {"copy/u1x1", "copy/u1x2", "copy/u2x1",
                          "copy/u2x2"}
    assert all(c["n"] == 1 and c["gbps"] > 0 for c in cells.values())
    # string keys survive the meta/JSON stash
    res.meta["by_knobs"] = s
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert set(back.meta["by_knobs"]["all"]) == set(cells)
    # default grouping is unchanged: one 'copy' cell
    assert set(res.summarize()["all"]) == {"copy"}


def test_golden_v4_roundtrip():
    """The schema-v4 fixture: points carry unroll/interleave and a full
    istream classification dict; the file round-trips bit-identically
    through from_dict/to_dict."""
    res = BenchResult.from_json(DATA / "result_v4.json")
    assert res.schema_version == 4
    assert res.points
    knobs = {(p.unroll, p.interleave) for p in res.points}
    assert len(knobs) > 1                   # a real knob sweep
    labels = set()
    for p in res.points:
        assert p.istream is not None
        assert p.istream["label"] in ("bandwidth-bound", "issue-bound")
        assert p.istream["per_iter"]["loads"] > 0
        labels.add(p.istream["label"])
    assert labels == {"bandwidth-bound", "issue-bound"}
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert back.points == res.points and back.schema_version == 4


@pytest.mark.parametrize("fname,ver", [
    ("result_v1.json", 1), ("result_v2.json", 2), ("result_v3.json", 3),
])
def test_golden_older_schemas_default_istream_knobs(fname, ver):
    """v1-v3 files load with the v4 defaults: unroll=interleave=1,
    istream=None — the back-compat promise for the new columns."""
    res = BenchResult.from_json(DATA / fname)
    assert res.schema_version == ver
    for p in res.points:
        assert p.unroll == 1 and p.interleave == 1 and p.istream is None


def test_knob_names_exposes_full_surface():
    names = knob_names()
    assert "unroll" in names and "interleave" in names
    assert names == tuple(sorted(names))
