"""Sequence-sharded flash-decode: numerical equivalence to the plain decode
attention path, on a real 8-device mesh (subprocess)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_arch, reduced
from repro.distributed.sharding import ShardCtx
from repro.launch.mesh import make_mesh
from repro.models.attention import gqa_decode
from repro.models.common import init_params
from repro.models.attention import gqa_specs
from repro.serve.flash_decode import seq_sharded_gqa_decode

# zamba2-ish shared attention config, reduced
cfg = reduced(get_arch("zamba2-2.7b"))
mesh = make_mesh((1, 4, 2), ("pod", "data", "model"))
ctx = ShardCtx(mesh)
p = init_params(gqa_specs(cfg, cfg.d_model), jax.random.key(0))
B, S = 1, 64              # batch 1: the long_500k regime (seq shards over data)
hd = cfg.resolved_head_dim
x = jax.random.normal(jax.random.key(1), (B, 1, cfg.d_model), jnp.float32) * 0.3
ck = jax.random.normal(jax.random.key(2), (B, S, cfg.n_kv_heads, hd),
                       jnp.bfloat16) * 0.3
cv = jax.random.normal(jax.random.key(3), (B, S, cfg.n_kv_heads, hd),
                       jnp.bfloat16) * 0.3
pos = jnp.int32(37)

with jax.set_mesh(mesh):
    ref_o, ref_k, ref_v = jax.jit(
        lambda x, ck, cv: gqa_decode(cfg, p, x, ck, cv, pos))(x, ck, cv)
    out_o, out_k, out_v = jax.jit(
        lambda x, ck, cv: seq_sharded_gqa_decode(ctx, cfg, p, x, ck, cv, pos))(
        x, ck, cv)

do = float(jnp.max(jnp.abs(out_o.astype(jnp.float32) - ref_o.astype(jnp.float32))))
dk = float(jnp.max(jnp.abs(out_k.astype(jnp.float32) - ref_k.astype(jnp.float32))))
dv = float(jnp.max(jnp.abs(out_v.astype(jnp.float32) - ref_v.astype(jnp.float32))))
assert do < 2e-2, f"output diverges: {do}"
assert dk == 0.0 and dv == 0.0, f"cache update differs: {dk} {dv}"
print("FLASH_DECODE_OK", do)
"""


def test_seq_sharded_decode_matches_plain():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "FLASH_DECODE_OK" in r.stdout
