"""Config registry: the 10 assigned archs, param counts, shape support."""
import pytest

from repro.configs import SHAPES, get_arch, list_archs, param_count, reduced

ASSIGNED = {
    "whisper-medium", "deepseek-v2-236b", "arctic-480b", "chameleon-34b",
    "mamba2-2.7b", "internlm2-20b", "phi3-medium-14b", "stablelm-3b",
    "granite-3-2b", "zamba2-2.7b",
}

# advertised sizes (billions) and tolerance — checks the configs actually
# build the models their names claim
EXPECTED_B = {
    "whisper-medium": (0.76, 0.15), "deepseek-v2-236b": (236, 0.06),
    "arctic-480b": (480, 0.05), "chameleon-34b": (34, 0.05),
    "mamba2-2.7b": (2.7, 0.1), "internlm2-20b": (20, 0.05),
    "phi3-medium-14b": (14, 0.08), "stablelm-3b": (2.8, 0.15),
    "granite-3-2b": (2.5, 0.1), "zamba2-2.7b": (2.7, 0.15),
}


def test_all_assigned_archs_registered():
    assert set(list_archs()) == ASSIGNED


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_param_count_matches_name(name):
    total, active = param_count(get_arch(name))
    exp, tol = EXPECTED_B[name]
    assert abs(total / 1e9 - exp) / exp < max(tol, 0.1) + 0.05, \
        f"{name}: {total/1e9:.2f}B vs expected {exp}B"
    assert 0 < active <= total


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_config_valid(name):
    cfg = reduced(get_arch(name))
    assert cfg.n_layers <= 2 or cfg.family == "hybrid"
    assert cfg.d_model <= 256
    assert cfg.family == get_arch(name).family


def test_shape_assignments():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skips():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runnable = {a for a in list_archs()
                if get_arch(a).supports_shape(SHAPES["long_500k"])[0]}
    assert runnable == {"mamba2-2.7b", "zamba2-2.7b"}


def test_whisper_is_encdec_with_decode():
    cfg = get_arch("whisper-medium")
    ok, _ = cfg.supports_shape(SHAPES["decode_32k"])
    assert ok, "whisper is encoder-decoder, decode must be supported"


def test_moe_configs():
    ds = get_arch("deepseek-v2-236b")
    assert ds.moe.n_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.n_shared_experts == 2
    assert ds.mla.kv_lora_rank == 512
    arc = get_arch("arctic-480b")
    assert arc.moe.n_experts == 128 and arc.moe.top_k == 2
    assert arc.moe.dense_residual
