"""Pallas kernels vs pure-jnp oracles, swept over shapes and dtypes
(interpret mode on CPU; the kernel bodies are the TPU programs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.buffers import working_set
from repro.kernels.flash_attention.ops import flash
from repro.kernels.flash_attention.ref import reference as flash_ref
from repro.kernels.membench import ops as mb_ops
from repro.kernels.membench.ref import reference as mb_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import reference as ssd_ref

# ---------------------------------------------------------------------------
# membench kernels — sweep shapes x dtypes x mixes x block shapes x streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbytes", [16 * 1024, 128 * 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mix", ["load_sum", "copy", "fma_4", "mxu"])
@pytest.mark.parametrize("block_rows,streams", [(8, 1), (32, 2), (16, 4)])
def test_membench_vs_ref(nbytes, dtype, mix, block_rows, streams):
    x = working_set(nbytes, dtype=dtype)
    if x.shape[0] % (block_rows * streams):
        pytest.skip("shape not divisible")
    fn = mb_ops.make_kernel(mix=mix, block_rows=block_rows, streams=streams,
                            interpret=True)
    out = fn(x)
    ref = mb_ref(mix, x, depth=4, block_rows=block_rows)
    n = x.size
    # (v,1/v,-v,-1/v) sums cancel exactly; tolerance scales with n*eps*|v|
    eps = 1e-7 if dtype == jnp.float32 else 8e-3
    atol = max(n * eps * 1.3, 1e-4)
    if mix == "copy":
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=atol)
    else:
        assert abs(float(out) - float(ref)) < atol, (mix, float(out), float(ref))


def test_membench_stream_orders_equivalent():
    """All stream interleavings must visit every block exactly once."""
    x = working_set(64 * 1024)
    outs = [float(mb_ops.make_kernel("load_sum", block_rows=16, streams=s)(x))
            for s in (1, 2, 4)]
    assert max(outs) - min(outs) < 1e-3


def test_membench_work_accounting():
    x = working_set(32 * 1024)
    b, f = mb_ops.work_per_call("load_sum", x)
    assert b == x.size * 4 and f == x.size
    b, f = mb_ops.work_per_call("copy", x)
    assert b == 2 * x.size * 4
    b, f = mb_ops.work_per_call("fma_8", x)
    assert f == 16 * x.size


# ---------------------------------------------------------------------------
# flash attention — shape/dtype sweep vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [
    (2, 128, 8, 4, 64), (1, 256, 4, 4, 32), (2, 128, 8, 2, 64),
    (1, 128, 16, 16, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, H, KV, D, causal, dtype):
    ks = jax.random.split(jax.random.key(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash(q, k, v, causal=causal, q_block=64, kv_block=64)
    ref = flash_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_block_shape_invariance():
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 4, 32), jnp.float32)
    a = flash(q, k, v, causal=True, q_block=256, kv_block=256)
    b = flash(q, k, v, causal=True, q_block=32, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan — vs token-level recurrence oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,P,N,Q", [
    (4, 128, 32, 16, 32), (2, 256, 64, 32, 64), (1, 64, 16, 8, 16),
])
def test_ssd_vs_recurrence(BH, S, P, N, Q):
    ks = jax.random.split(jax.random.key(BH + S), 4)
    xdt = jax.random.normal(ks[0], (BH, S, P)) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[1], (BH, S))) * 0.3
    Bm = jax.random.normal(ks[2], (BH, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (BH, S, N)) * 0.5
    y, st = ssd(xdt, dA, Bm, Cm, chunk=Q)
    yr, sr = ssd_ref(xdt, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_invariance():
    ks = jax.random.split(jax.random.key(5), 4)
    BH, S, P, N = 2, 128, 16, 8
    xdt = jax.random.normal(ks[0], (BH, S, P)) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[1], (BH, S))) * 0.3
    Bm = jax.random.normal(ks[2], (BH, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (BH, S, N)) * 0.5
    y1, s1 = ssd(xdt, dA, Bm, Cm, chunk=32)
    y2, s2 = ssd(xdt, dA, Bm, Cm, chunk=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_model_ssd_matches_kernel():
    """models/ssm.ssd_chunked (XLA path) == Pallas kernel on the same inputs."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.key(7), 4)
    B, S, H, P, N = 2, 128, 4, 16, 8
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.5 + 0.1
    A = -jnp.ones((H,)) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.5
    y_model, st_model = ssd_chunked(xh, dt, A, Bm, Cm, 32)
    # kernel expects per-head streams and dt-weighted x
    xdt = (xh * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dA = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(B * H, S)
    Bk = jnp.broadcast_to(Bm, (B, S, H, N)).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Ck = jnp.broadcast_to(Cm, (B, S, H, N)).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y_k, _ = ssd(xdt.astype(jnp.float32), dA, Bk, Ck, chunk=32)
    y_k = y_k.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_k),
                               rtol=5e-3, atol=5e-3)
