"""Core membench: buffer discipline (hypothesis), timing, sweep, analysis."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

from repro.core import analysis, buffers, instruction_mix, sweep, timing
from repro.core.machine_model import TPU_V5E, HardwareSpec, MemLevel, detect_host

# ---------------------------------------------------------------------------
# buffer init — the paper's denormal-avoiding discipline (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1e-3, max_value=1e3, allow_nan=False),
       st.integers(min_value=4, max_value=4096))
def test_init_pattern_no_denormals(value, n):
    arr = buffers.init_pattern(n, value, jnp.float32)
    a = np.asarray(arr)
    assert np.all(np.isfinite(a))
    assert not buffers.has_denormals(a)
    # the (v, 1/v, -v, -1/v) cycle
    np.testing.assert_allclose(a[0], value, rtol=1e-6)
    if n >= 4:
        np.testing.assert_allclose(a[1], 1.0 / value, rtol=1e-6)
        np.testing.assert_allclose(a[2], -value, rtol=1e-6)
        np.testing.assert_allclose(a[3], -1.0 / value, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2**12, max_value=2**22))
def test_working_set_size(nbytes):
    x = buffers.working_set(nbytes)
    real = x.size * x.dtype.itemsize
    assert abs(real - nbytes) / nbytes < 0.3 or real >= 8 * 128 * 4
    assert x.shape[1] == 128 and x.shape[0] % 8 == 0


def test_init_rejects_bad_values():
    with pytest.raises(ValueError):
        buffers.init_pattern(16, 0.0)
    with pytest.raises(ValueError):
        buffers.init_pattern(16, float("inf"))


# ---------------------------------------------------------------------------
# timing harness — cumulative-mean discipline
# ---------------------------------------------------------------------------

def test_timing_harness():
    x = buffers.working_set(64 * 1024)
    t = timing.time_fn(lambda: instruction_mix.run_mix("load_sum", x, 4),
                       reps=5, warmup=1, bytes_per_call=float(64 * 1024 * 4))
    assert t.mean_s > 0 and len(t.times_s) == 5
    assert len(t.cumulative_mean_s) == 5
    np.testing.assert_allclose(t.cumulative_mean_s[-1], t.mean_s, rtol=1e-9)
    assert t.gbps > 0


def test_time_fn_rejects_degenerate_repetition_counts():
    """reps=0 used to sail through to np.mean([]) — a RuntimeWarning and a
    NaN TimingResult instead of an error (BenchSpec validates its own path;
    this guards direct callers of the harness)."""
    fn = lambda: instruction_mix.run_mix("load_sum",
                                         buffers.working_set(4096), 1)
    with pytest.raises(ValueError, match="reps"):
        timing.time_fn(fn, reps=0)
    with pytest.raises(ValueError, match="reps"):
        timing.time_fn(fn, reps=-1)
    with pytest.raises(ValueError, match="warmup"):
        timing.time_fn(fn, reps=1, warmup=-1)
    # warmup=0 stays valid (first timed rep compiles)
    t = timing.time_fn(fn, reps=1, warmup=0)
    assert t.mean_s > 0


def test_spec_validates_repetition_and_device_knobs():
    """The BenchSpec layer of the same regression: degenerate knobs surface
    at construction, before any timing is spent."""
    from repro.bench import BenchSpec, BenchSpecError
    with pytest.raises(BenchSpecError):
        BenchSpec(reps=0)
    with pytest.raises(BenchSpecError):
        BenchSpec(warmup=-1)
    with pytest.raises(BenchSpecError):
        BenchSpec(devices=0)
    assert BenchSpec(reps=1, warmup=0).devices == 1


def test_mix_kernels_defeat_hoisting():
    """2x passes must take ~2x work: if XLA hoisted the body out of the loop,
    time would be flat in passes.  We check the *result* scales (the accumulator
    sums passes once per iteration)."""
    x = buffers.working_set(32 * 1024, value=2.0)
    a = float(instruction_mix.run_mix("fma_2", x, 2))
    b = float(instruction_mix.run_mix("fma_2", x, 4))
    # fma chain on (v,1/v,-v,-1/v) data: each pass adds ~constant epsilon-sum
    assert abs(b) > abs(a) * 1.5 or abs(b - 2 * a) < 1e-2 * max(abs(a), 1.0)


# ---------------------------------------------------------------------------
# sweep + analysis
# ---------------------------------------------------------------------------

def test_small_sweep_and_analysis():
    res = sweep.run_sweep(sizes=[16 * 2**10, 256 * 2**10, 4 * 2**20],
                          mix_names=["load_sum", "fma_8"], reps=3,
                          target_bytes=3e7)
    assert len(res.points) == 6
    for p in res.points:
        assert p.gbps > 0
    host = detect_host()
    model = analysis.build_machine_model(res, host)
    assert model.level_bw, "no levels attributed"
    for lvl, mixes in model.mix_penalty.items():
        assert max(mixes.values()) == pytest.approx(1.0)


def test_ridge_depth_detects_knee():
    """Synthetic sweep where fma_16 is slower => ridge at 16."""
    pts = []
    for k, bw in [(1, 100.0), (4, 99.0), (16, 50.0), (64, 20.0)]:
        pts.append(sweep.SweepPoint(nbytes=16 * 2**10, mix=f"fma_{k}",
                                    dtype="float32", passes=1, mean_s=1e-3,
                                    std_s=0, gbps=bw, gflops=0))
    pts.append(sweep.SweepPoint(nbytes=16 * 2**10, mix="load_sum",
                                dtype="float32", passes=1, mean_s=1e-3,
                                std_s=0, gbps=100.0, gflops=0))
    res = sweep.SweepResult(points=pts)
    k = analysis.ridge_depth(res, (8 * 2**10, 32 * 2**10))
    assert k == 16


def test_sweep_json_roundtrip(tmp_path):
    res = sweep.run_sweep(sizes=[16 * 2**10], mix_names=["load_sum"], reps=2,
                          target_bytes=1e6)
    p = tmp_path / "sweep.json"
    res.to_json(p)
    back = sweep.SweepResult.from_json(p)
    assert back.points[0].gbps == pytest.approx(res.points[0].gbps)


def test_machine_model_spec():
    assert TPU_V5E.peak_flops == 197e12
    assert TPU_V5E.levels[-1].read_bw == 819e9
    assert TPU_V5E.link_bw == 50e9
    host = detect_host()
    assert host.levels[-1].name == "DRAM"
