"""Per-arch smoke tests (assigned requirement): reduced config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs, reduced
from repro.distributed.sharding import make_smoke_ctx
from repro.models.common import init_params, vocab_padded
from repro.models.registry import build, init_cache, make_batch
from repro.models.variant import BASELINE
from repro.optim import adamw
from repro.train.step import make_train_step

CTX = make_smoke_ctx()
B, S = 2, 64


def _setup(name):
    cfg = reduced(get_arch(name))
    model = build(cfg)
    params = init_params(model.param_specs(), jax.random.key(0))
    batch = make_batch(cfg, (B, S), jax.random.key(1))
    return cfg, model, params, batch


@pytest.mark.parametrize("name", sorted(list_archs()))
def test_forward_loss(name):
    cfg, model, params, batch = _setup(name)
    with jax.set_mesh(CTX.mesh):
        loss, metrics = jax.jit(lambda p, b: model.loss(p, b, CTX, BASELINE))(
            params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{name}: NaN loss"
    # random init => loss near ln(vocab)
    assert 0.5 * jnp.log(cfg.vocab_size) < loss < 2.0 * jnp.log(cfg.vocab_size)


@pytest.mark.parametrize("name", sorted(list_archs()))
def test_train_step(name):
    cfg, model, params, batch = _setup(name)
    step_fn = make_train_step(cfg, CTX, opt_cfg=adamw.AdamWConfig(lr=1e-3),
                              variant=BASELINE)
    opt = adamw.init_state(params)
    with jax.set_mesh(CTX.mesh):
        new_params, new_opt, metrics = jax.jit(step_fn)(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, f"{name}: train step did not update params"
    for g in jax.tree.leaves(new_params):
        assert not bool(jnp.any(jnp.isnan(g))), f"{name}: NaN in updated params"


@pytest.mark.parametrize("name", sorted(list_archs()))
def test_prefill_shapes(name):
    cfg, model, params, batch = _setup(name)
    with jax.set_mesh(CTX.mesh):
        if cfg.family == "encdec":
            logits, cache = jax.jit(
                lambda p, b: model.prefill(p, b, CTX, BASELINE))(params, batch)
        else:
            logits, cache = jax.jit(
                lambda p, t: model.prefill(p, t, CTX, BASELINE))(
                params, batch["tokens"])
    assert logits.shape == (B, vocab_padded(cfg))
    assert not bool(jnp.isnan(logits).any()), f"{name}: NaN prefill logits"
    assert len(jax.tree.leaves(cache)) > 0


@pytest.mark.parametrize("name", sorted(list_archs()))
def test_decode_step(name):
    cfg, model, params, batch = _setup(name)
    cache = init_cache(cfg, B, S)
    with jax.set_mesh(CTX.mesh):
        logits, new_cache = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, CTX, BASELINE))(
            params, cache, batch["tokens"][:, :1], jnp.int32(0))
    assert logits.shape == (B, 1, vocab_padded(cfg))
    assert not bool(jnp.isnan(logits).any()), f"{name}: NaN decode logits"
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("name", ["granite-3-2b", "mamba2-2.7b", "zamba2-2.7b",
                                  "deepseek-v2-236b"])
def test_decode_matches_prefill(name):
    """Token-by-token decode reproduces the prefill logits (bf16 tolerance)."""
    cfg, model, params, batch = _setup(name)
    with jax.set_mesh(CTX.mesh):
        ref_logits, _ = jax.jit(
            lambda p, t: model.prefill(p, t, CTX, BASELINE))(
            params, batch["tokens"])
        cache = init_cache(cfg, B, S)
        dec = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, CTX,
                                                             BASELINE))
        c = cache
        for i in range(S):
            lg, c = dec(params, c, batch["tokens"][:, i:i + 1], jnp.int32(i))
    # compare on true vocab (padded cols are -1e30 in both)
    V = cfg.vocab_size
    diff = float(jnp.max(jnp.abs(lg[:, 0, :V] - ref_logits[:, :V])))
    assert diff < 0.75, f"{name}: decode/prefill diverge by {diff}"


def test_logit_pad_mask():
    cfg, model, params, batch = _setup("granite-3-2b")
    with jax.set_mesh(CTX.mesh):
        logits, _ = jax.jit(
            lambda p, t: model.prefill(p, t, CTX, BASELINE))(
            params, batch["tokens"])
    vp = vocab_padded(cfg)
    if vp > cfg.vocab_size:
        assert bool(jnp.all(logits[:, cfg.vocab_size:] < -1e29))
