"""repro.bench: spec validation + JSON round-trip, mix-registry parity across
backends (identical bytes/flops accounting from the shared registry), Runner
smoke in interpret mode, CLI surface, and the relative-baseline fix."""
import json

import pytest

from repro.bench import (BenchSpec, BenchSpecError, BenchResult, Runner,
                         get_mix, mix_names, quick_spec, registry)
from repro.bench.result import BenchPoint, SCHEMA_VERSION

TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1, passes=1)


# ---------------------------------------------------------------------------
# BenchSpec validation + serialization
# ---------------------------------------------------------------------------

def test_spec_defaults_valid():
    s = BenchSpec()
    assert s.backend == "xla" and s.mixes == ("load_sum",)


@pytest.mark.parametrize("kw", [
    dict(backend="cuda"),
    dict(mixes=("nope",)),
    dict(mixes=()),
    dict(mixes=("load_only",)),            # pallas-only mix on xla backend
    dict(sizes=(0,)),
    dict(sizes=()),
    dict(streams=0),
    dict(devices=0),
    dict(devices=2),                       # xla is single-device
    dict(devices=2, backend="pallas"),     # pallas is single-device
    dict(block_rows=12),                   # not a multiple of 8
    dict(reps=0),
    dict(passes=0),
    dict(target_bytes=0),
    dict(dtype="floatzz"),
])
def test_spec_rejects(kw):
    with pytest.raises(BenchSpecError):
        BenchSpec(**kw)


def test_spec_accepts_load_only_on_pallas():
    s = BenchSpec(mixes=("load_only",), backend="pallas")
    assert s.mixes == ("load_only",)


def test_spec_json_roundtrip(tmp_path):
    s = BenchSpec(mixes=("load_sum", "fma_4"), sizes=(2**14, 2**20),
                  backend="pallas", block_rows=32, streams=2, reps=3,
                  tags=("unit",))
    p = tmp_path / "spec.json"
    s.to_json(p)
    back = BenchSpec.from_json(p)
    assert back == s
    # lists coming from hand-written JSON coerce to tuples
    d = json.loads(s.to_json())
    assert BenchSpec.from_dict(d) == s


def test_spec_rejects_unknown_fields_and_newer_version():
    with pytest.raises(BenchSpecError):
        BenchSpec.from_dict({"mixes": ["load_sum"], "bogus": 1})
    with pytest.raises(BenchSpecError):
        BenchSpec.from_dict({"spec_version": 99})


def test_spec_replace_is_frozen():
    s = BenchSpec()
    with pytest.raises(Exception):
        s.backend = "pallas"
    assert s.replace(backend="pallas").backend == "pallas"


# ---------------------------------------------------------------------------
# mix registry — declared once, consumed by both backends
# ---------------------------------------------------------------------------

def test_registry_parity_accounting():
    """Every dual-backend mix runs through the Runner on a tiny buffer on BOTH
    backends and reports byte-identical bytes/flops accounting."""
    runner = Runner()
    for name in mix_names():
        m = get_mix(name)
        per_backend = {}
        for backend in m.backends:
            spec = BenchSpec(mixes=(name,), backend=backend, **TINY)
            res = runner.run(spec)
            (pt,) = res.points
            assert pt.gbps > 0 and pt.mean_s > 0, (name, backend)
            per_backend[backend] = (pt.bytes_per_call, pt.flops_per_call)
        assert len(set(per_backend.values())) == 1, (name, per_backend)


def test_registry_accounting_values():
    n = 1024
    nbytes = 4 * n
    assert get_mix("load_sum").bytes_per_pass(nbytes) == nbytes
    assert get_mix("load_sum").flops_per_pass(n) == n
    assert get_mix("copy").bytes_per_pass(nbytes) == 2 * nbytes
    assert get_mix("triad").bytes_per_pass(nbytes) == 3 * nbytes
    assert get_mix("triad").flops_per_pass(n) == 2 * n
    assert get_mix("fma_8").flops_per_pass(n) == 16 * n
    assert get_mix("mxu").flops_per_pass(n) == 2 * 128 * n
    assert get_mix("load_only").backends == ("pallas",)


def test_legacy_views_delegate_to_registry():
    from repro.core import instruction_mix
    from repro.core.buffers import working_set
    from repro.kernels.membench import ops as mb_ops
    legacy = instruction_mix.mixes()
    reg = registry()
    for name, m in legacy.items():
        if name in reg:
            assert m == reg[name], name
    x = working_set(32 * 1024)
    assert mb_ops.work_per_call("copy", x) == (2 * x.size * 4, 0.0)


# ---------------------------------------------------------------------------
# Runner smoke + versioned results
# ---------------------------------------------------------------------------

def test_runner_smoke_and_result_roundtrip(tmp_path):
    spec = BenchSpec(mixes=("load_sum", "copy"), sizes=(16 * 2**10, 64 * 2**10),
                     reps=2, warmup=1, target_bytes=1e6)
    res = Runner().run(spec)
    assert len(res.points) == 4
    assert res.schema_version == SCHEMA_VERSION
    assert res.spec["backend"] == "xla"
    assert res.machine["jax"] and res.machine["device_platform"]
    for p in res.points:
        assert p.backend == "xla" and p.gbps > 0 and p.passes >= 1
    path = tmp_path / "res.json"
    res.to_json(path)
    back = BenchResult.from_json(path)
    assert back.points == res.points
    assert back.spec == res.spec


def test_runner_pallas_interpret_smoke():
    spec = BenchSpec(mixes=("load_only", "load_sum"), backend="pallas",
                     block_rows=8, streams=2, **TINY)
    res = Runner().run(spec)
    assert [p.mix for p in res.points] == ["load_only", "load_sum"]
    assert all(p.streams == 2 and p.block_rows == 8 for p in res.points)


def test_runner_auto_passes():
    from repro.bench.runner import pick_passes
    assert pick_passes(1024, 1e6) == 976
    assert pick_passes(10**9, 1e6) == 1
    spec = BenchSpec(mixes=("load_sum",), sizes=(16 * 2**10,), reps=2,
                     warmup=1, target_bytes=1e6)
    (pt,) = Runner().run(spec).points
    assert pt.passes == pick_passes(pt.nbytes, 1e6)


def test_xla_backend_rejects_unsupported_knobs():
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("copy",), streams=2, **TINY))
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("copy",), block_rows=8, **TINY))


def test_baseline_relative_zero_anchor():
    """A 0.0 first measurement must STAY the baseline (rel=nan), not silently
    re-anchor on the next point — the fig1 `base = base or gbps` bug."""
    def pt(streams, gbps):
        return BenchPoint(nbytes=1024, mix="load_sum", dtype="float32",
                          backend="xla", passes=1, streams=streams,
                          block_rows=None, reps=1, bytes_per_call=1024.0,
                          flops_per_call=0.0, mean_s=1e-3, std_s=0.0,
                          min_s=1e-3, gbps=gbps, gflops=0.0)
    res = BenchResult(points=[pt(1, 0.0), pt(2, 5.0), pt(4, 10.0)])
    rels = res.baseline_relative(group_key=lambda p: p.nbytes,
                                 is_baseline=lambda p: p.streams == 1)
    import math
    assert all(math.isnan(r) for _, r in rels)   # anchored on the 0.0 point
    res2 = BenchResult(points=[pt(1, 5.0), pt(2, 10.0)])
    rels2 = dict(res2.baseline_relative(group_key=lambda p: p.nbytes,
                                        is_baseline=lambda p: p.streams == 1))
    assert rels2[pt(2, 10.0)] == pytest.approx(2.0)


def test_time_fn_warmup_zero():
    """warmup=0 must not crash (the UnboundLocalError on `out`): the first
    timed rep simply pays compilation."""
    import jax.numpy as jnp
    from repro.core import timing
    t = timing.time_fn(lambda: jnp.zeros(8), reps=2, warmup=0,
                       bytes_per_call=1.0)
    assert len(t.times_s) == 2 and t.mean_s > 0


def test_spec_warmup_zero_end_to_end():
    """BenchSpec validation allows warmup=0, so the Runner must run it."""
    spec = BenchSpec(mixes=("load_sum",), sizes=(16 * 2**10,), reps=2,
                     warmup=0, passes=1)
    (pt,) = Runner().run(spec).points
    assert pt.mean_s > 0 and pt.gbps > 0


@pytest.mark.parametrize("backend", ["xla", "pallas", "sharded"])
def test_sweep_releases_buffers(monkeypatch, backend):
    """A size sweep holds ONE working set at a time — earlier sizes' buffers
    are collectible while later sizes are being timed, not retained for the
    whole run (as the build-everything-up-front case list used to do), and
    the compiled-case cache never pins one either."""
    import gc
    import weakref
    from repro.bench.backends import get_backend
    from repro.core import buffers, timing
    refs = []
    real_ws = buffers.working_set

    def spy_ws(nbytes, **kw):
        x = real_ws(nbytes, **kw)
        refs.append(weakref.ref(x))
        return x

    # also track placed copies (sharded swaps the host buffer for a mesh one)
    be = get_backend(backend)
    real_prep = be.prepare_buffer

    def spy_prep(spec, x):
        y = real_prep(spec, x)
        refs.append(weakref.ref(y))
        return y

    peak = 0
    real_tf = timing.time_fn

    def spy_tf(fn, *a, **kw):
        nonlocal peak
        gc.collect()
        alive = {id(r()) for r in refs if r() is not None}
        peak = max(peak, len(alive))
        return real_tf(fn, *a, **kw)

    monkeypatch.setattr(buffers, "working_set", spy_ws)
    monkeypatch.setattr(be, "prepare_buffer", spy_prep)
    monkeypatch.setattr(timing, "time_fn", spy_tf)
    sizes = (16 * 2**10, 64 * 2**10, 256 * 2**10, 1 * 2**20)
    runner = Runner()
    runner.run(BenchSpec(mixes=("load_sum", "copy"), backend=backend,
                         sizes=sizes, reps=2, warmup=1, passes=1))
    assert len(refs) >= len(sizes)
    assert peak == 1, f"{peak} working sets live at once on {backend}"
    assert runner._cases            # cached cases outlive the buffers
    gc.collect()
    assert all(r() is None for r in refs)


def test_compiled_case_cache_hits():
    """Re-running a spec (or sweeping an unrelated knob) re-times cached
    kernels instead of re-tracing them."""
    r = Runner()
    base = BenchSpec(mixes=("load_sum",), **TINY)
    r.run(base)
    assert (r.cache_hits, r.cache_misses) == (0, 1)
    r.run(base)
    assert (r.cache_hits, r.cache_misses) == (1, 1)
    r.run_many([base, base.replace(streams=2)])   # streams=2 is a new case
    assert (r.cache_hits, r.cache_misses) == (2, 2)
    fresh = Runner()                               # cache is per-instance
    fresh.run(base)
    assert (fresh.cache_hits, fresh.cache_misses) == (0, 1)


def test_runner_compare_filters_mixes():
    out = Runner().compare(BenchSpec(mixes=("load_sum",), **TINY))
    assert set(out) == {"xla", "pallas"}
    for res in out.values():
        assert res.points[0].mix == "load_sum"


def test_runner_compare_filters_knob_conflicts():
    """streams=2 keeps load_sum on xla and drops copy instead of aborting."""
    spec = BenchSpec(mixes=("load_sum", "copy"), backend="pallas", streams=2,
                     sizes=(128 * 2**10,), reps=2, warmup=1, passes=1)
    out = Runner().compare(spec)
    assert [p.mix for p in out["xla"].points] == ["load_sum"]
    assert [p.mix for p in out["pallas"].points] == ["load_sum", "copy"]


def test_run_many_envelope_records_all_specs():
    base = BenchSpec(mixes=("load_sum",), **TINY)
    res = Runner().run_many([base.replace(streams=s) for s in (1, 2)])
    assert "many" in res.spec and len(res.spec["many"]) == 2
    assert {p.streams for p in res.points} == {1, 2}
    single = Runner().run_many([base])
    assert "many" not in single.spec   # one spec: plain envelope


def test_run_many_unions_meta_across_specs():
    """The merged envelope must describe ALL merged points — sizes/mixes are
    the union across specs, not results[0]'s lists."""
    a = BenchSpec(mixes=("load_sum",), **TINY)
    b = a.replace(mixes=("copy",), sizes=(64 * 2**10,))
    res = Runner().run_many([a, b])
    assert res.meta["sizes"] == [16 * 2**10, 64 * 2**10]
    assert res.meta["mixes"] == ["load_sum", "copy"]
    assert {p.mix for p in res.points} == {"load_sum", "copy"}
    # uniform dtype/reps stay scalar (the common knob sweep)
    assert res.meta["dtype"] == "float32" and res.meta["reps"] == a.reps


def test_run_many_unions_dtype_and_reps_when_specs_disagree():
    """results[0]'s scalar dtype/reps silently misdescribed a merge of
    disagreeing specs — they now union to first-seen-ordered lists."""
    a = BenchSpec(mixes=("load_sum",), **TINY)
    b = a.replace(dtype="bfloat16", reps=3)
    res = Runner().run_many([a, b])
    assert res.meta["dtype"] == ["float32", "bfloat16"]
    assert res.meta["reps"] == [a.reps, 3]
    # each point still carries its own knobs
    assert {p.dtype for p in res.points} == {"float32", "bfloat16"}
    assert {p.reps for p in res.points} == {a.reps, 3}


def test_by_size_resolves_requested_and_real_sizes():
    """working_set_shape rounds 50_000 B to whole (8, 128) f32 tiles;
    by_size(spec size) used to return [] for any rounded size."""
    spec = BenchSpec(mixes=("load_sum",), sizes=(50_000,), reps=2, warmup=1,
                     passes=1)
    res = Runner().run(spec)
    (p,) = res.points
    assert p.nbytes != 50_000 and p.nbytes_requested == 50_000
    assert res.by_size(50_000) == [p] == res.by_size(p.nbytes)
    # the envelope's sizes list (requested) now always resolves
    assert all(res.by_size(s) for s in res.meta["sizes"])


def test_summarize_band_and_meta_are_json_spec_compliant():
    """An unbounded band edge must serialize as null, not Infinity — JSON
    parsers outside Python reject non-finite literals."""
    res = Runner().run(BenchSpec(mixes=("load_sum",), **TINY))
    # an 8K L1 puts the 16K point in the unbounded DRAM band (lo = 16K)
    res.meta["summary"] = res.summarize(levels=(("L1", 8 * 2**10),
                                                ("DRAM", None)))
    summary = res.meta["summary"]
    assert summary["DRAM"]["load_sum"]["band"] == (16 * 2**10, None)
    # belt and suspenders: even a raw inf/nan stashed into meta serializes
    # as null rather than emitting non-JSON "Infinity"/"NaN" literals
    res.meta["raw"] = {"inf": float("inf"), "nan": float("nan")}
    text = res.to_json()
    assert "Infinity" not in text and "NaN" not in text
    back = json.loads(text)
    assert back["meta"]["summary"]["DRAM"]["load_sum"]["band"][1] is None
    assert back["meta"]["raw"] == {"inf": None, "nan": None}


def test_compare_records_skipped():
    """compare must not drop mixes/backends silently: every skipped
    (backend, mix) pair lands in meta['skipped'] with its reason."""
    spec = BenchSpec(mixes=("load_sum", "copy"), backend="pallas", streams=2,
                     sizes=(128 * 2**10,), reps=2, warmup=1, passes=1)
    out = Runner().compare(spec)
    sk = out["xla"].meta["skipped"]
    assert [m for m, _ in sk["xla"]] == ["copy"]       # streams>1 on copy
    assert "streams" in sk["xla"][0][1]
    assert all(res.meta["skipped"] == sk for res in out.values())


def test_compare_raises_when_nothing_runnable():
    """A comparison where every backend is skipped raises with the skip map
    instead of returning an empty dict."""
    spec = BenchSpec(mixes=("load_only",), backend="pallas", **TINY)
    with pytest.raises(BenchSpecError, match="load_only"):
        Runner().compare(spec, backends=("xla",))


def test_cli_compare_prints_skipped(capsys):
    from repro.bench import cli
    rc = cli.main(["compare", "--mixes", "load_sum,copy", "--streams", "2",
                   "--sizes", "128K", "--reps", "2"])
    assert rc == 0
    cap = capsys.readouterr()
    assert "# skipped xla/copy:" in cap.out


def test_spec_devices_roundtrip_and_v1_backcompat():
    s = BenchSpec(mixes=("load_sum",), backend="sharded", devices=1, **TINY)
    d = json.loads(s.to_json())
    assert d["spec_version"] == 4 and d["devices"] == 1
    assert BenchSpec.from_dict(d) == s
    old = {k: v for k, v in d.items()
           if k not in ("devices", "unroll", "interleave")}  # a v1 spec file
    old["spec_version"] = 1
    assert BenchSpec.from_dict(old).devices == 1
    assert BenchSpec.from_dict(old).unroll == 1
    assert BenchSpec.from_dict(old).interleave == 1


def test_result_v1_backcompat_defaults_devices():
    pt = dict(nbytes=1024, mix="load_sum", dtype="float32", backend="xla",
              passes=1, streams=1, block_rows=None, reps=1,
              bytes_per_call=1024.0, flops_per_call=0.0, mean_s=1e-3,
              std_s=0.0, min_s=1e-3, gbps=1.0, gflops=0.0)
    res = BenchResult.from_dict({"schema_version": 1, "points": [pt]})
    assert res.points[0].devices == 1
    assert res.schema_version == 1


def test_custom_backend_registration_usable():
    from repro.bench.backends import _BACKENDS, register_backend
    import jax.numpy as jnp

    class EchoBackend:
        name = "echo-test"

        def supports(self, mix):
            return mix.name == "load_sum"

        def validate(self, spec):
            pass

        def build(self, spec, mix, x, passes):
            return lambda: jnp.sum(x)

    register_backend(EchoBackend())
    try:
        spec = BenchSpec(mixes=("load_sum",), backend="echo-test", **TINY)
        (pt,) = Runner().run(spec).points
        assert pt.backend == "echo-test" and pt.mean_s > 0
        with pytest.raises(BenchSpecError):   # support set still enforced
            BenchSpec(mixes=("copy",), backend="echo-test", **TINY)
    finally:
        _BACKENDS.pop("echo-test", None)


def test_fma_family_open_ended():
    """Any fma_k depth is a valid mix with synthesized accounting (the
    registry lists only the canonical ladder)."""
    m = get_mix("fma_3")
    assert m.flops_per_elem == 6.0 and m.fma_depth == 3
    assert "fma_3" not in registry()
    with pytest.raises(KeyError):
        get_mix("fma_zz")
    (pt,) = Runner().run(BenchSpec(mixes=("fma_3",), **TINY)).points
    assert pt.flops_per_call == 6.0 * (pt.nbytes / 4)


def test_pallas_explicit_block_rows_never_clamped():
    """An explicit block_rows that doesn't fit the buffer errors (on both
    backends) rather than being silently adjusted and mis-recorded."""
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("load_sum",), backend="pallas",
                               block_rows=512, **TINY))


def test_legacy_mixes_restricts_fma_depths():
    from repro.core.instruction_mix import mixes
    got = sorted(mixes(fma_depths=(2,)))
    assert got == ["copy", "fma_2", "load_sum", "mxu", "triad"]


# ---------------------------------------------------------------------------
# legacy sweep wrapper + CLI
# ---------------------------------------------------------------------------

def test_legacy_run_sweep_routes_through_runner():
    from repro.core import sweep
    res = sweep.run_sweep(sizes=[16 * 2**10], mix_names=["load_sum"], reps=2,
                          target_bytes=1e6)
    assert isinstance(res, sweep.SweepResult)
    assert res.points[0].mix == "load_sum" and res.points[0].gbps > 0
    assert res.meta["mixes"] == ["load_sum"]


def test_cli_run_and_list(tmp_path, capsys):
    from repro.bench import cli
    out = tmp_path / "r.json"
    rc = cli.main(["run", "--quick", "--sizes", "16K", "--reps", "2",
                   "--out", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["schema_version"] == SCHEMA_VERSION and d["points"]
    assert cli.main(["list-mixes"]) == 0
    cap = capsys.readouterr()
    assert "load_only" in cap.out and "triad" in cap.out


def test_cli_compare(capsys):
    from repro.bench import cli
    rc = cli.main(["compare", "--mixes", "load_sum", "--sizes", "16K",
                   "--reps", "2"])
    assert rc == 0
    cap = capsys.readouterr()
    assert "load_sum" in cap.out and "mismatch" not in cap.out
