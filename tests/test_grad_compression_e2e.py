"""End-to-end: training with int8 error-feedback gradient compression still
learns, and tracks the uncompressed run closely."""
import jax
import pytest

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def _run(tmp_path, compression: bool, tag: str):
    cfg = reduced(get_arch("granite-3-2b"))
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    tcfg = TrainConfig(steps=15, ckpt_every=100, ckpt_dir=str(tmp_path / tag),
                       log_every=5, grad_compression=compression,
                       opt=adamw.AdamWConfig(lr=2e-3, warmup_steps=2,
                                             total_steps=15))
    tr = Trainer(cfg, (4, 64), mesh, tcfg)
    _, _, hist = tr.train(resume=False)
    return [h["loss"] for h in hist]


def test_compressed_training_learns(tmp_path):
    plain = _run(tmp_path, False, "plain")
    comp = _run(tmp_path, True, "comp")
    assert comp[-1] < comp[0], "compressed run did not learn"
    # error feedback keeps the compressed trajectory close to the plain one
    assert abs(comp[-1] - plain[-1]) < 0.15, (plain, comp)
