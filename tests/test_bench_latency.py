"""The loaded-latency axis (latency_chase + spec ``load``) through the
bench stack: spec validation gates, chase-permutation structure, per-mix
pass sizing, xla/pallas composite parity, the compiled-case cache-key
no-alias guarantee for ``load``, accounting audit (checked, never waived),
the schema-v5 golden round-trip + older-schema defaults, the
``summarize(key="load")`` grouped view, and the per-level knee fit round-
tripping through ``FittedMachineModel`` (fitted-model schema v3)."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.bench import BenchResult, BenchSpec, BenchSpecError, Runner
from repro.bench.mixes import GEN_SWEEPS_PER_PASS, get_mix
from repro.bench.runner import CHASE_TARGET_STEPS, pick_passes

DATA = Path(__file__).parent / "data"
TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1)

#: shared so repeated cases hit the compiled-case cache
RUNNER = Runner()


# ---------------------------------------------------------------------------
# spec validation gates
# ---------------------------------------------------------------------------

def test_load_rejects_negative():
    with pytest.raises(BenchSpecError, match="load"):
        BenchSpec(mixes=("latency_chase",), load=-1, **TINY)


def test_load_requires_chase_mix():
    with pytest.raises(BenchSpecError, match="latency"):
        BenchSpec(mixes=("copy",), load=1, **TINY)
    # chase-only spec accepts any load; an idle (load=0) mixed spec is fine
    BenchSpec(mixes=("latency_chase",), load=2, **TINY)
    BenchSpec(mixes=("copy", "latency_chase"), **TINY)


def test_sharded_gates_devices_equals_load_plus_one():
    """The mesh composite places the probe on shard 0 and one generator per
    sibling shard — the spec's devices must equal load + 1 (a backend rule,
    enforced at Runner time like the other mesh gates)."""
    spec = BenchSpec(mixes=("latency_chase",), backend="sharded", load=2,
                     devices=2, **TINY)
    with pytest.raises(BenchSpecError, match="load"):
        Runner().run(spec)


# ---------------------------------------------------------------------------
# chase permutation: one full cycle per part
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("parts", [1, 4])
def test_chase_perm_is_one_cycle_per_part(parts):
    from repro.core.instruction_mix import chase_perm
    rows, lanes = 16, 128
    perm = chase_perm((rows, lanes), parts=parts)
    flat = np.asarray(perm).reshape(-1)
    m = flat.size // parts
    for s in range(parts):
        seg = flat[s * m:(s + 1) * m]
        # part-local indices only (a mesh shard / pallas tile never reaches
        # outside its own slice)
        assert seg.min() >= 0 and seg.max() < m
        j, seen = 0, 0
        for _ in range(m):
            j = seg[j]
            seen += 1
            if j == 0:
                break
        assert seen == m, f"part {s}: cycle length {seen} != {m}"


def test_chase_kernel_walks_to_zero():
    """A full-cycle walk starting at index 0 ends at index 0 every pass, so
    the accumulated output is exactly 0.0 — value-level proof the kernel
    walks complete cycles (a broken perm or early exit lands elsewhere)."""
    import jax.numpy as jnp
    from repro.core.instruction_mix import chase_perm, k_chase
    perm = jnp.asarray(chase_perm((16, 128)))
    assert float(k_chase(perm, 4)) == 0.0
    assert float(k_chase(perm, 4, unroll=2)) == 0.0


# ---------------------------------------------------------------------------
# per-mix pass sizing (the latency-mix pick_passes fix)
# ---------------------------------------------------------------------------

def test_pick_passes_sizes_chase_by_steps_not_bytes():
    """A chase case's wall time scales with steps x latency, not bytes /
    bandwidth: pass count must come from CHASE_TARGET_STEPS, not the byte
    target (which would demand ~6000 passes of a 32K buffer)."""
    chase = get_mix("latency_chase")
    n = 8192                                  # 32 KiB of f32
    p = pick_passes(n * 4, mix=chase, n_elems=n)
    assert p == CHASE_TARGET_STEPS // n
    assert p < pick_passes(n * 4)             # far below the byte sizing
    # a chain longer than the step target still walks once end to end
    assert pick_passes(2**21 * 4, mix=chase, n_elems=2**21) == 1
    # mesh: only the probe shard's slice is walked
    assert pick_passes(n * 4, mix=chase, n_elems=n, devices=4) \
        == CHASE_TARGET_STEPS // (n // 4)
    # non-chase mixes keep the byte sizing
    assert pick_passes(n * 4, mix=get_mix("copy")) == pick_passes(n * 4)


# ---------------------------------------------------------------------------
# compiled-case cache key: load never aliases
# ---------------------------------------------------------------------------

def test_cache_no_alias_regression_load():
    """Two specs differing ONLY in ``load`` compile two distinct cases: the
    second run must be a cache MISS (aliasing would time the idle walk and
    report it as loaded), and identical knobs re-hit."""
    from repro.bench.backends import _NON_CASE_FIELDS, case_knobs
    assert "load" not in _NON_CASE_FIELDS
    assert "load" in {name for name, _ in case_knobs(BenchSpec(**TINY))}
    r = Runner()
    base = BenchSpec(mixes=("latency_chase",), passes=4, **TINY)
    r.run(base)
    misses = r.cache_misses
    r.run(base.replace(load=1))
    assert r.cache_misses == misses + 1, "load=1 aliased the idle case"
    r.run(base.replace(load=1))
    assert r.cache_misses == misses + 1


# ---------------------------------------------------------------------------
# the measured composite: point fields, parity, monotonicity
# ---------------------------------------------------------------------------

def _lat_points(backend, loads, sizes=(16 * 2**10,)):
    specs = [BenchSpec(mixes=("latency_chase",), sizes=sizes, passes=4,
                       backend=backend, reps=3, warmup=1, load=load)
             for load in loads]
    return RUNNER.run_many(specs).points


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_chase_points_carry_latency_axes(backend):
    pts = _lat_points(backend, (0, 2))
    by_load = {p.load: p for p in pts}
    assert set(by_load) == {0, 2}
    idle, loaded = by_load[0], by_load[2]
    assert idle.latency_ns and idle.latency_ns > 0
    assert idle.gen_gbps == 0.0
    assert loaded.gen_gbps > 0
    # composite accounting: the loaded case declares the generator traffic
    # (2 generators x GEN_SWEEPS_PER_PASS sweeps) on top of the probe walk
    assert loaded.bytes_per_call == pytest.approx(
        idle.bytes_per_call * (1 + 2 * GEN_SWEEPS_PER_PASS), rel=1e-6)
    assert loaded.flops_per_call > 0 and idle.flops_per_call == 0


def test_loaded_latency_monotone_under_load():
    """Generators contend with the probe, so per-step latency at load=4
    must not beat idle — the loaded-latency curve's defining property (the
    time-shared composite makes this deterministic: every probe pass pays
    for 4 x GEN_SWEEPS_PER_PASS generator sweeps)."""
    by_load = {p.load: p for p in _lat_points("xla", (0, 4))}
    assert by_load[4].latency_ns >= by_load[0].latency_ns


def test_non_chase_points_default_latency_axes():
    res = RUNNER.run(BenchSpec(mixes=("copy",), passes=4, **TINY))
    for p in res.points:
        assert p.load == 0 and p.latency_ns is None and p.gen_gbps is None


# ---------------------------------------------------------------------------
# accounting audit: chase is checked, never waived
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("load", [0, 1])
def test_chase_audit_checked_clean(backend, load):
    """The chase's dependent loads are unhoistable and its composite's
    traffic has exact calibrated expectations — the auditor must CHECK the
    case (no waiver class for latency mixes) and find it clean."""
    from repro.audit import audit_case
    shape = (64, 128)
    spec = BenchSpec(mixes=("latency_chase",), sizes=(shape[0] * shape[1] * 4,),
                     backend=backend, passes=4, reps=2, warmup=0, load=load)
    a = audit_case(spec, "latency_chase", shape, "float32", 4)
    assert not a.waived, a.waived_reason
    assert a.ok, [c.detail for c in a.failures]
    assert a.expected is not None and a.expected["loads"] > 0


# ---------------------------------------------------------------------------
# schema v5: golden round-trip, back-compat defaults, summarize(key="load")
# ---------------------------------------------------------------------------

def test_golden_v5_roundtrip():
    """The schema-v5 fixture: a real loaded-latency sweep whose points
    carry (load, latency_ns, gen_gbps) and whose meta stashes the knee fit;
    the file round-trips bit-identically through from_dict/to_dict."""
    res = BenchResult.from_json(DATA / "result_v5.json")
    assert res.schema_version == 5
    assert {p.load for p in res.points} == {0, 1, 2}
    for p in res.points:
        assert p.latency_ns > 0
        assert (p.gen_gbps > 0) == (p.load > 0)
    fit = res.meta["loaded_latency"]["fit"]
    assert fit["levels"]["all"]["idle_latency_ns"] > 0
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert back.points == res.points and back.schema_version == 5


@pytest.mark.parametrize("fname,ver", [
    ("result_v1.json", 1), ("result_v2.json", 2), ("result_v3.json", 3),
    ("result_v4.json", 4),
])
def test_golden_older_schemas_default_latency_axes(fname, ver):
    """v1-v4 files load with the v5 defaults: load=0, latency_ns=None,
    gen_gbps=None — the back-compat promise for the new columns."""
    res = BenchResult.from_json(DATA / fname)
    assert res.schema_version == ver
    for p in res.points:
        assert p.load == 0 and p.latency_ns is None and p.gen_gbps is None


def test_summarize_string_key_groups_by_load():
    res = BenchResult.from_json(DATA / "result_v5.json")
    cells = res.summarize(key="load")["all"]
    # string keys (JSON object keys) so the summary survives a meta stash
    assert set(cells) == {"0", "1", "2"}
    assert all(c["n"] == 1 for c in cells.values())
    back = BenchResult.from_dict(json.loads(res.to_json()))
    back.meta["by_load"] = res.summarize(key="load")
    assert set(json.loads(back.to_json())["meta"]["by_load"]["all"]) \
        == {"0", "1", "2"}


# ---------------------------------------------------------------------------
# knee fit + FittedMachineModel round-trip (fitted-model schema v3)
# ---------------------------------------------------------------------------

def _synth_points(loads_lats_gens, nbytes=16 * 2**10):
    from repro.bench.result import BenchPoint
    return [BenchPoint(nbytes=nbytes, mix="latency_chase", dtype="float32",
                       backend="xla", passes=8, streams=1, block_rows=None,
                       reps=3, bytes_per_call=1.0, flops_per_call=0.0,
                       mean_s=1e-3, std_s=0.0, min_s=1e-3, gbps=1.0,
                       gflops=0.0, load=load, latency_ns=lat, gen_gbps=gen)
            for load, lat, gen in loads_lats_gens]


def test_fit_knee_picks_last_point_on_plateau():
    from repro.characterize import fit_knee
    pts = _synth_points([(0, 40.0, 0.0), (1, 45.0, 2.0), (2, 55.0, 3.5),
                         (4, 120.0, 4.0)])
    knee = fit_knee(pts, factor=1.5)
    assert knee["idle_latency_ns"] == 40.0
    assert knee["knee_load"] == 2 and knee["knee_gen_gbps"] == 3.5
    assert knee["max_latency_ns"] == 120.0
    assert knee["loads"] == [0, 1, 2, 4]
    # a single load level is not a curve
    assert fit_knee(_synth_points([(0, 40.0, 0.0)])) is None


def test_fit_loaded_bands_per_level():
    from repro.characterize import fit_loaded
    res = BenchResult(points=_synth_points(
        [(0, 40.0, 0.0), (2, 80.0, 3.0)], nbytes=16 * 2**10)
        + _synth_points([(0, 90.0, 0.0), (2, 100.0, 5.0)], nbytes=8 * 2**20))
    fit = fit_loaded(res, levels=(("L1", 256 * 2**10), ("DRAM", None)),
                     factor=1.5)
    assert set(fit["levels"]) == {"L1", "DRAM"}
    assert fit["levels"]["L1"]["idle_latency_ns"] == 40.0
    assert fit["levels"]["L1"]["knee_load"] == 0      # 80 > 1.5 x 40
    assert fit["levels"]["DRAM"]["knee_load"] == 2    # 100 < 1.5 x 90
    assert fit["levels"]["DRAM"]["band"][1] is None   # JSON-safe open edge


def test_fitted_model_roundtrips_loaded_latency():
    from repro.characterize import (FITTED_SCHEMA_VERSION, FittedMachineModel,
                                    fit_knee)
    assert FITTED_SCHEMA_VERSION == 3
    knee = fit_knee(_synth_points([(0, 40.0, 0.0), (2, 50.0, 3.0)]))
    model = FittedMachineModel(
        loaded_latency={"factor": 1.5, "levels": {"all": knee}})
    back = FittedMachineModel.from_dict(json.loads(model.to_json()))
    assert back.loaded_latency == model.loaded_latency
    assert back.schema_version == 3
    # pre-v3 files load with the default (None)
    old = {k: v for k, v in model.to_dict().items()
           if k not in ("loaded_latency",)}
    old["schema_version"] = 2
    assert FittedMachineModel.from_dict(old).loaded_latency is None
