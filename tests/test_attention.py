"""Attention: chunked online-softmax vs naive oracle, folded variant, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # optional dep; see pyproject [test]
    from _hypothesis_stub import given, settings, st

from repro.kernels.flash_attention.ref import reference as naive_attention
from repro.models.attention import (apply_rope, chunked_attention,
                                    folded_causal_attention, rope_freqs)


def _qkv(key, B, Sq, Sk, H, KV, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, Sq, H, D), dtype),
            jax.random.normal(ks[1], (B, Sk, KV, D), dtype),
            jax.random.normal(ks[2], (B, Sk, KV, D), dtype))


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D,causal,blk", [
    (2, 128, 128, 8, 4, 64, True, 32),
    (1, 64, 64, 4, 4, 32, False, 16),
    (2, 96, 96, 6, 2, 16, True, 32),       # uneven: Sk % blk != 0 path
    (1, 128, 1500 % 128 + 64, 4, 4, 32, False, 64),  # padded KV
])
def test_chunked_matches_naive(B, Sq, Sk, H, KV, D, causal, blk):
    q, k, v = _qkv(jax.random.key(1), B, Sq, Sk, H, KV, D)
    out = chunked_attention(q, k, v, causal=causal, kv_block=blk, q_block=blk)
    ref = naive_attention(q, k, v, causal=causal)
    # chunked_attention computes in bf16 (production mixed precision); the
    # oracle is f32 => bf16-epsilon tolerance
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_folded_matches_masked():
    q, k, v = _qkv(jax.random.key(2), 2, 256, 256, 8, 4, 32)
    masked = chunked_attention(q, k, v, causal=True, kv_block=64, q_block=64)
    folded = folded_causal_attention(q, k, v, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(masked),
                               rtol=2e-5, atol=2e-5)


def test_query_blocking_invariance():
    q, k, v = _qkv(jax.random.key(3), 1, 256, 256, 4, 4, 32)
    a = chunked_attention(q, k, v, causal=True, kv_block=256, q_block=256)
    b = chunked_attention(q, k, v, causal=True, kv_block=64, q_block=64)
    # different block decompositions reorder bf16 accumulation
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# RoPE properties
# ---------------------------------------------------------------------------

def test_rope_norm_preserving():
    inv = rope_freqs(64, 1.0, 10000.0)
    x = jax.random.normal(jax.random.key(0), (1, 16, 2, 64))
    y = apply_rope(x, jnp.arange(16), inv)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_position():
    """<R(p)q, R(p)k> depends only on... identical positions => unrotated dot."""
    inv = rope_freqs(32, 1.0, 10000.0)
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 32))
    for p in (0, 5, 100):
        qp = apply_rope(q, jnp.array([p]), inv)
        kp = apply_rope(k, jnp.array([p]), inv)
        d0 = float(jnp.sum(q * k))
        dp = float(jnp.sum(qp * kp))
        assert abs(d0 - dp) < 1e-3


def test_partial_rope():
    """rope_pct=0.25 must rotate only the first quarter of dims."""
    inv = rope_freqs(64, 0.25, 10000.0)
    assert inv.shape[0] * 2 == 16
    x = jax.random.normal(jax.random.key(3), (1, 4, 1, 64))
    y = apply_rope(x, jnp.arange(4), inv)
    np.testing.assert_allclose(np.asarray(x[..., 16:]), np.asarray(y[..., 16:]))
    assert not np.allclose(np.asarray(x[..., :16])[0, 1:],
                           np.asarray(y[..., :16])[0, 1:])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 96]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]))
def test_chunked_attention_property(b, s, heads):
    """softmax rows sum to one => output within convex hull of V rows."""
    h, kv = heads
    q, k, v = _qkv(jax.random.key(b * s), b, s, s, h, kv, 16)
    out = np.asarray(chunked_attention(q, k, v, causal=True, kv_block=32,
                                       q_block=32))
    vmax = np.asarray(v).max()
    vmin = np.asarray(v).min()
    assert out.max() <= vmax + 1e-4 and out.min() >= vmin - 1e-4
