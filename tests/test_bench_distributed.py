"""The distributed (multi-process) execution layer: single-process parity of
the `distributed` backend vs `sharded`/`xla`, env-var autodetection, the
local launcher end-to-end (2 coordinated subprocesses, forced host devices),
gathered-result semantics (straggler merge, process meta), schema-v3
round-trips, and the v1/v2 golden back-compat promise.

Multi-process tests spawn subprocesses (conftest keeps this process at one
device by design); they share one launcher run via a module fixture to keep
the suite fast."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.bench import (BenchPoint, BenchResult, BenchSpec, BenchSpecError,
                         Runner, mix_names)
from repro.bench import distributed as dist

SRC = str(Path(__file__).resolve().parents[1] / "src")
DATA = Path(__file__).parent / "data"
TINY = dict(sizes=(16 * 2**10,), reps=2, warmup=1, passes=1)


def _clean_env(**extra):
    env = dict(os.environ, PYTHONPATH=SRC, **extra)
    for k in ("XLA_FLAGS", "REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
              "REPRO_PROCESS_ID"):
        env.pop(k, None)
    env.update(extra)
    return env


# ---------------------------------------------------------------------------
# single-process (in-process): the backend degenerates to sharded
# ---------------------------------------------------------------------------

def test_distributed_accounting_parity_vs_sharded_and_xla():
    """Accounting is registry-sourced, so xla == sharded == distributed for
    every oracle-runnable mix, by construction."""
    runner = Runner()
    assert mix_names("distributed") == mix_names("sharded") == mix_names("xla")
    for name in ("load_sum", "triad", "rw_2to1"):
        acct = {}
        for backend in ("xla", "sharded", "distributed"):
            spec = BenchSpec(mixes=(name,), backend=backend, **TINY)
            (pt,) = runner.run(spec).points
            assert pt.gbps > 0 and pt.mean_s > 0, (name, backend)
            acct[backend] = (pt.bytes_per_call, pt.flops_per_call)
        assert len(set(acct.values())) == 1, (name, acct)


def test_distributed_knob_rules_match_the_oracles():
    with pytest.raises(BenchSpecError):
        BenchSpec(mixes=("load_only",), backend="distributed", **TINY)
    with pytest.raises(BenchSpecError):
        Runner().run(BenchSpec(mixes=("copy",), backend="distributed",
                               streams=2, **TINY))
    with pytest.raises(BenchSpecError, match="devices=2"):
        Runner().run(BenchSpec(mixes=("load_sum",), backend="distributed",
                               devices=2, **TINY))   # 1 visible device here


def test_gather_result_is_identity_single_process():
    res = Runner().run(BenchSpec(mixes=("load_sum",), backend="distributed",
                                 **TINY))
    assert dist.gather_result(res) is res
    assert res.machine["process_count"] == 1
    assert res.machine["process_index"] == 0
    assert res.machine["local_device_count"] >= 1


# ---------------------------------------------------------------------------
# coordination plumbing (no jax.distributed needed)
# ---------------------------------------------------------------------------

def test_env_info_and_env_active(monkeypatch):
    for k in (dist.ENV_COORDINATOR + dist.ENV_NUM_PROCESSES
              + dist.ENV_PROCESS_ID):
        monkeypatch.delenv(k, raising=False)
    assert dist.env_info() == (None, None, None)
    assert not dist.env_active()
    monkeypatch.setenv("REPRO_COORDINATOR", "127.0.0.1:1234")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    assert dist.env_info() == ("127.0.0.1:1234", 2, 1)
    assert dist.env_active()
    # JAX's own names are honored as fallback
    monkeypatch.delenv("REPRO_COORDINATOR")
    monkeypatch.setenv("JAX_COORDINATOR_ADDRESS", "10.0.0.1:9")
    assert dist.env_info()[0] == "10.0.0.1:9"


def test_ensure_initialized_noop_outside_launch(monkeypatch):
    for k in (dist.ENV_COORDINATOR + dist.ENV_NUM_PROCESSES
              + dist.ENV_PROCESS_ID):
        monkeypatch.delenv(k, raising=False)
    assert dist.ensure_initialized() is False
    # nproc set but no process id: a loud error beats a silent hang
    monkeypatch.setenv("REPRO_COORDINATOR", "127.0.0.1:1234")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    with pytest.raises(RuntimeError, match="process id"):
        dist.ensure_initialized()


def test_launch_local_validates_args():
    with pytest.raises(ValueError, match="processes"):
        dist.launch_local(["true"], processes=0)
    with pytest.raises(ValueError, match="devices_per_process"):
        dist.launch_local(["true"], processes=1, devices_per_process=0)


def test_launch_local_propagates_worker_failure(tmp_path):
    rc = dist.launch_local(
        [sys.executable, "-c", "import sys; sys.exit(3)"],
        processes=2, timeout=60, stream_to=open(os.devnull, "w"))
    assert rc == 3


# ---------------------------------------------------------------------------
# 2-process launcher end-to-end (subprocesses; one shared run)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gathered(tmp_path_factory):
    """One 2-process x 2-device launcher run: CLI `launch` -> workers run the
    distributed backend over the 4-device global mesh -> process 0 writes
    the gathered result."""
    out = tmp_path_factory.mktemp("dist") / "gathered.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.bench", "launch",
         "--processes", "2", "--devices-per-process", "2",
         "--timeout", "520", "--out", str(out),
         "--mixes", "load_sum,copy", "--sizes", "1M", "--reps", "2"],
        capture_output=True, text=True, env=_clean_env(), timeout=560)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return json.loads(out.read_text()), r.stdout + r.stderr


def test_launcher_gathers_one_result_on_process0(gathered):
    d, log = gathered
    assert d["schema_version"] == 6
    assert d["machine"]["process_count"] == 2
    assert d["machine"]["process_index"] == 0
    assert d["machine"]["local_device_counts"] == [2, 2]
    assert d["machine"]["device_count"] == 4
    # all points on the full global mesh, positive throughput
    assert [p["mix"] for p in d["points"]] == ["load_sum", "copy"]
    assert all(p["devices"] == 4 and p["gbps"] > 0 for p in d["points"])
    # per-process timing rows kept for skew inspection; the merged point is
    # the straggler: its mean is the max across processes
    rows = d["meta"]["per_process_mean_s"]
    assert len(rows) == 2 and len(rows[0]) == len(d["points"])
    for i, p in enumerate(d["points"]):
        assert p["mean_s"] == pytest.approx(max(r[i] for r in rows))
        assert p["gbps"] == pytest.approx(
            p["bytes_per_call"] / p["mean_s"] / 1e9)
    # non-primary processes report instead of writing
    assert "[p1] # process 1/2 done" in log


def test_gathered_result_matches_sharded_accounting(gathered):
    """The acceptance criterion: a 2-process gathered run's per-point
    bytes/flops equals the single-process `sharded` backend at the same
    global device count (4), mix for mix — parity by construction."""
    d, _ = gathered
    snippet = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
from repro.bench import BenchSpec, Runner
res = Runner().run(BenchSpec(mixes=("load_sum", "copy"), sizes=(2**20,),
                             backend="sharded", devices=4, reps=2))
print(json.dumps([[p.mix, p.nbytes, p.bytes_per_call, p.flops_per_call]
                  for p in res.points]))
"""
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=_clean_env(), timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    sharded = json.loads(r.stdout.strip().splitlines()[-1])
    distributed = [[p["mix"], p["nbytes"], p["bytes_per_call"],
                    p["flops_per_call"]] for p in d["points"]]
    assert sharded == distributed


def test_gathered_result_roundtrips_as_v6(gathered):
    d, _ = gathered
    res = BenchResult.from_dict(d)
    assert res.schema_version == 6
    assert all(isinstance(p, BenchPoint) for p in res.points)
    # by_size resolves the requested size (1M here survives rounding intact)
    assert len(res.by_size(2**20)) == 2
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert back.points == res.points and back.machine == res.machine


def test_distributed_mesh_covers_every_process_or_raises():
    """devices < processes must fail loudly (a process with no shard can't
    represent the computation), and the round-robin device order spreads
    intermediate counts one-per-process."""
    snippet = r"""
from repro.bench import distributed as dist
dist.ensure_initialized()
import jax
from repro.bench import BenchSpec, BenchSpecError, Runner
from repro.bench.backends import get_backend
assert jax.process_count() == 2 and jax.device_count() == 4
devs = get_backend("distributed")._mesh_devices()
assert [d.process_index for d in devs] == [0, 1, 0, 1], devs
try:
    Runner().run(BenchSpec(mixes=("load_sum",), backend="distributed",
                           devices=1, sizes=(16 * 2**10,), reps=2,
                           warmup=1, passes=1))
except BenchSpecError as e:
    assert "no mesh shard" in str(e), e
else:
    raise AssertionError("devices=1 with 2 processes should be rejected")
# devices=2: one device per process via round-robin -> runs fine
res = Runner().run(BenchSpec(mixes=("load_sum",), backend="distributed",
                             devices=2, sizes=(16 * 2**10,), reps=2,
                             warmup=1, passes=1))
res = dist.gather_result(res)
assert res.points[0].devices == 2 and res.points[0].gbps > 0
print("COVERAGE_OK")
"""
    rc = dist.launch_local([sys.executable, "-c", snippet], processes=2,
                           devices_per_process=2, timeout=520,
                           stream_to=sys.stderr)
    assert rc == 0


# ---------------------------------------------------------------------------
# golden back-compat: v1/v2 files keep loading next to v3
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname,ver", [("result_v1.json", 1),
                                       ("result_v2.json", 2)])
def test_pre_v3_goldens_still_load_with_defaults(fname, ver):
    res = BenchResult.from_json(DATA / fname)
    assert res.schema_version == ver
    assert all(p.nbytes_requested is None for p in res.points)
    # pre-v3 points only resolve by real size; no crash on requested lookup
    assert res.by_size(res.points[0].nbytes)
    d = json.loads(res.to_json())
    assert d["schema_version"] == ver


def test_v3_golden_records_process_topology():
    res = BenchResult.from_json(DATA / "result_v3.json")
    assert res.schema_version == 3
    assert res.machine["process_count"] == 2
    assert res.machine["local_device_counts"] == [2, 2]
    assert all(p.devices == 4 and p.nbytes_requested for p in res.points)
    assert len(res.meta["per_process_mean_s"]) == 2
