"""repro.obs: span tracer, metrics registry, run ledger, regression gate.

The expensive part (one real traced Runner run) happens once in a module
fixture; everything trace-shaped asserts against those events, everything
ledger-shaped against that result.  CLI behaviors (overwrite refusal,
history/diff exit codes) go through ``cli.main`` in-process.
"""
import dataclasses
import json
import math

import pytest

from repro.bench import BenchSpec, Runner
from repro.bench.result import REP_SAMPLE_LIMIT, BenchResult
from repro.obs import ledger, metrics, trace
from repro.obs.trace import (Tracer, merge_process_traces, span_coverage,
                             validate_chrome)


@pytest.fixture(autouse=True)
def _tracer_reset():
    """CLI --trace enables the global tracer; never leak that into the
    next test (the zero-overhead test asserts it is OFF)."""
    yield
    trace.configure(enabled=False, clear=True)


# ---------------------------------------------------------------------------
# tracer unit behavior (private Tracer instances — no global state)
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_balance():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner"):
            pass
        with tr.span("inner2", cat="x", knob=3):
            pass
    evs = tr.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["args"]["depth"] == 0
    assert by_name["inner"]["args"]["depth"] == 1
    assert by_name["inner2"]["args"]["knob"] == 3
    # children close before the parent -> appear first, contained inside
    o, i = by_name["outer"], by_name["inner"]
    assert i["ts"] >= o["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]


def test_span_balanced_under_exception():
    tr = Tracer(enabled=True)
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("boom"):
                raise RuntimeError("body failed")
    evs = tr.events()
    assert [e["name"] for e in evs] == ["boom", "outer"]
    assert evs[0]["args"]["error"] == "RuntimeError"
    assert evs[1]["args"]["error"] == "RuntimeError"
    # the stack is balanced: a new span starts at depth 0 again
    with tr.span("after"):
        pass
    assert tr.events()[-1]["args"]["depth"] == 0


def test_disabled_tracer_is_allocation_free_noop():
    tr = Tracer(enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b", cat="x", big=list(range(10)))
    assert s1 is s2                     # the shared _NULL_SPAN singleton
    with s1:
        pass
    tr.event("e")
    assert tr.events() == []


def test_timed_path_never_touches_spans_when_disabled(monkeypatch):
    """The zero-overhead guarantee: with tracing off, ``time_fn`` must run
    the original untraced loop — a span() call anywhere in it would raise
    here."""
    import jax.numpy as jnp

    from repro.core import timing

    def explode(*a, **k):
        raise AssertionError("span() called on the disabled timed path")

    assert not trace.get_tracer().enabled
    monkeypatch.setattr(Tracer, "span", explode)
    monkeypatch.setattr(Tracer, "event", explode)
    x = jnp.ones((8, 8))
    t = timing.time_fn(lambda: x + 1, reps=3, warmup=1, bytes_per_call=1.0)
    assert len(t.times_s) == 3


def test_timing_samples_bounded():
    from repro.core.timing import TimingResult
    t = TimingResult(times_s=[float(i + 1) for i in range(100)])
    assert t.samples(10) == tuple(float(i + 1) for i in range(90, 100))
    assert len(t.samples()) == 100
    # the (mean, std, min) triple still covers ALL reps
    assert t.mean_s == pytest.approx(50.5)


def test_merge_process_traces_restamps_and_orders():
    def ev(name, ts, pid):
        return {"name": name, "cat": "c", "ph": "X", "ts": ts, "dur": 1.0,
                "pid": pid, "tid": 1, "args": {"depth": 0}}
    # per-process streams with colliding OS pids and interleaved timestamps
    p0 = [ev("a", 0.0, 9999), ev("b", 5.0, 9999)]
    p1 = [ev("c", 1.0, 9999), ev("d", 5.0, 9999)]
    merged = merge_process_traces([p0, p1])
    assert [e["pid"] for e in merged] == [0, 1, 0, 1]
    assert [e["name"] for e in merged] == ["a", "c", "b", "d"]
    assert [e["ts"] for e in merged] == sorted(e["ts"] for e in merged)
    # inputs are not mutated (the gather reuses local event lists)
    assert p0[0]["pid"] == 9999


def test_validate_chrome_catches_malformed_events():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 1, "tid": 1}]}
    assert validate_chrome(ok) == []
    assert validate_chrome({}) == ["traceEvents missing or not a list"]
    assert validate_chrome({"traceEvents": [{"ph": "X"}]})
    bad_dur = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                                "pid": 1, "tid": 1}]}
    assert any("dur" in p for p in validate_chrome(bad_dur))
    bad_ph = {"traceEvents": [{"name": "a", "ph": "?", "ts": 0.0,
                               "pid": 1, "tid": 1}]}
    assert any("phase" in p for p in validate_chrome(bad_ph))


def test_trace_write_formats(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("s"):
        tr.event("e")
    chrome = tr.write(tmp_path / "t.json")
    doc = json.loads(chrome.read_text())
    assert validate_chrome(doc) == []
    assert doc["metadata"]["trace_format"] == trace.TRACE_FORMAT
    lines = tr.write(tmp_path / "t.jsonl").read_text().splitlines()
    head = json.loads(lines[0])
    assert head["trace_format"] == trace.TRACE_FORMAT
    assert [json.loads(ln)["name"] for ln in lines[1:]] == ["e", "s"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_scope_delta_and_merge():
    reg = metrics.MetricsRegistry()
    reg.inc("pre", 5)
    with reg.scope() as scope:
        reg.inc("hits")
        reg.inc("hits")
        reg.gauge_max("peak", 10)
        reg.gauge_max("peak", 4)        # high-water: ignored
        delta = scope.delta()
    assert delta == {"counters": {"hits": 2}, "gauges": {"peak": 10}}
    assert reg.snapshot()["counters"]["pre"] == 5
    merged = metrics.merge_obs([
        {"counters": {"a": 1}, "gauges": {"g": 5}, "runner": {"x": 1}},
        {"counters": {"a": 2, "b": 1}, "gauges": {"g": 3},
         "runner": {"x": 4}},
    ])
    assert merged == {"counters": {"a": 3, "b": 1}, "gauges": {"g": 5},
                      "runner": {"x": 4}}


# ---------------------------------------------------------------------------
# one real traced run — trace/result/obs agreement, the ledger's input
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    tr = trace.configure(enabled=True, clear=True)
    try:
        res = Runner().run(BenchSpec(
            mixes=("copy", "load_sum"), sizes=(64 * 2**10, 256 * 2**10),
            passes=4, reps=3, warmup=1))
        events = tr.events()
    finally:
        trace.configure(enabled=False, clear=True)
    return events, res


def test_traced_run_chrome_valid_and_covered(traced_run):
    events, _ = traced_run
    doc = {"traceEvents": events, "metadata": {}}
    assert validate_chrome(doc) == []
    # the acceptance bar: phase spans account for >= 95% of runner.run
    assert span_coverage(events) >= 0.95
    names = {e["name"] for e in events}
    assert {"runner.run", "runner.plan", "runner.size", "buffers.build",
            "runner.case", "timing.warmup", "timing.rep", "case.build",
            "cache", "backend.dispatch", "buffers.release"} <= names


def test_obs_counters_match_trace_events(traced_run):
    events, res = traced_run
    obs = res.meta["obs"]
    cache = [e for e in events if e["name"] == "cache"]
    hits = sum(e["args"]["outcome"] == "hit" for e in cache)
    misses = sum(e["args"]["outcome"] == "miss" for e in cache)
    assert obs["counters"].get("cache_hits", 0) == hits
    assert obs["counters"].get("cache_misses", 0) == misses == 4
    builds = sum(e["name"] == "buffers.build" for e in events)
    releases = sum(e["name"] == "buffers.release" for e in events)
    assert obs["counters"]["buffers_built"] == builds == 2
    assert obs["counters"]["buffers_released"] == releases == 2
    assert obs["gauges"]["peak_working_set_bytes"] == 256 * 2**10
    assert obs["runner"] == {"cache_hits": 0, "cache_misses": 4}


def test_rep_samples_on_points_roundtrip(traced_run):
    _, res = traced_run
    for p in res.points:
        assert p.rep_times_s is not None
        assert len(p.rep_times_s) == min(p.reps, REP_SAMPLE_LIMIT)
        assert all(t > 0 for t in p.rep_times_s)
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert back.points == res.points
    assert back.meta["obs"] == res.meta["obs"]


def test_points_with_rep_samples_stay_hashable(traced_run):
    """rep_times_s must canonicalize to a tuple on EVERY construction path
    (runner, from_dict, literal list): the frozen point is grouped in dicts
    by baseline_relative, and a list field breaks __hash__ — caught live by
    fig1 --quick, pinned here."""
    _, res = traced_run
    for p in res.points:
        assert isinstance(p.rep_times_s, tuple)
        hash(p)
    back = BenchResult.from_dict(json.loads(res.to_json()))
    assert all(isinstance(p.rep_times_s, tuple) for p in back.points)
    listy = dataclasses.replace(res.points[0], rep_times_s=[1e-3, 2e-3])
    assert listy.rep_times_s == (1e-3, 2e-3) and hash(listy) is not None
    rel = dict(res.baseline_relative(group_key=lambda p: p.nbytes))
    assert len(rel) == len(res.points)


# ---------------------------------------------------------------------------
# ledger + regression gate
# ---------------------------------------------------------------------------

def test_ledger_roundtrip_and_refs(traced_run, tmp_path):
    _, res = traced_run
    root = tmp_path / "hist"
    path, rec = ledger.append_record(res, cmd="run", root=root)
    assert path == root / "ledger.jsonl"
    assert (root / "VERSION").read_text().strip() == str(
        ledger.LEDGER_VERSION)
    assert rec["schema_version"] == res.schema_version
    assert len(rec["curves"]) == 4          # 2 mixes x 2 sizes
    for cell in rec["curves"]:
        assert cell["gbps"] > 0 and cell["n"] == 3
        assert cell["log_sigma"] >= 0
    records = ledger.read_ledger(root)
    assert records == [rec]
    # every accepted reference form resolves to the same record
    assert ledger.resolve_ref(-1, root=root) == rec
    assert ledger.resolve_ref("latest", root=root) == rec
    assert ledger.resolve_ref(rec["spec_digest"][:6], root=root) == rec
    out = tmp_path / "res.json"
    res.to_json(out)
    from_file = ledger.resolve_ref(str(out), root=root)
    assert [c["gbps"] for c in from_file["curves"]] == \
        [c["gbps"] for c in rec["curves"]]
    with pytest.raises(ValueError, match="cannot resolve"):
        ledger.resolve_ref("zzzz", root=root)
    with pytest.raises(ValueError, match="out of range"):
        ledger.resolve_ref(5, root=root)


def test_ledger_refuses_newer_version(tmp_path):
    root = tmp_path / "hist"
    root.mkdir()
    (root / "VERSION").write_text(f"{ledger.LEDGER_VERSION + 1}\n")
    with pytest.raises(ValueError, match="newer than supported"):
        ledger.append_record({"ledger_version": ledger.LEDGER_VERSION},
                             root=root)


def test_diff_self_is_identical_exit_0(traced_run, tmp_path):
    _, res = traced_run
    _, rec = ledger.append_record(res, root=tmp_path / "h")
    report = ledger.diff_records(rec, rec)
    assert report.identical and report.exit_code() == 0
    assert not report.regressions and not report.improvements


def test_diff_flags_real_drop_exit_2(traced_run, tmp_path):
    _, res = traced_run
    _, rec = ledger.append_record(res, root=tmp_path / "h")
    # pin the noise term: the traced fixture run uses 3 reps, whose measured
    # scatter can legitimately absorb even a 2x step (that behavior has its
    # own test below) — here the subject is the verdict/exit-code plumbing
    for cell in rec["curves"]:
        cell["log_sigma"] = 0.02
    slower = json.loads(json.dumps(rec))
    for cell in slower["curves"]:
        cell["gbps"] /= 1.5
    report = ledger.diff_records(rec, slower)
    assert report.exit_code() == 2
    assert len(report.regressions) == len(rec["curves"])
    for row in report.rows:
        assert row["verdict"] == "regression"
        assert row["ratio"] == pytest.approx(1 / 1.5)
    # the reverse direction is an improvement, not a regression
    back = ledger.diff_records(slower, rec)
    assert back.exit_code() == 0
    assert len(back.improvements) == len(rec["curves"])


def test_diff_noise_floor_absorbs_small_wobble(traced_run, tmp_path):
    _, res = traced_run
    _, rec = ledger.append_record(res, root=tmp_path / "h")
    wobble = json.loads(json.dumps(rec))
    for cell in wobble["curves"]:
        cell["gbps"] *= 0.97            # -3%: inside the 5% tolerance floor
    report = ledger.diff_records(rec, wobble, tolerance=0.05)
    assert report.exit_code() == 0 and not report.regressions
    # ... but a tight-tolerance, huge-sigma cell still needs z*sigma cleared
    noisy = json.loads(json.dumps(rec))
    for cell in noisy["curves"]:
        cell["gbps"] /= 1.10
        cell["log_sigma"] = 1.0         # per-rep scatter dwarfs the 10% drop
    report = ledger.diff_records(rec, noisy, tolerance=0.01)
    assert report.exit_code() == 0


def test_diff_reports_missing_and_added_cells(traced_run, tmp_path):
    _, res = traced_run
    _, rec = ledger.append_record(res, root=tmp_path / "h")
    shrunk = json.loads(json.dumps(rec))
    moved = shrunk["curves"].pop()
    extra = dict(moved, nbytes=moved["nbytes"] * 2)
    shrunk["curves"].append(extra)
    report = ledger.diff_records(rec, shrunk)
    assert len(report.missing) == 1 and len(report.added) == 1
    assert report.exit_code() == 0      # coverage drift is visible, not fatal


def test_cell_stats_sigma_from_samples(traced_run):
    _, res = traced_run
    rec = ledger.record_from_result(res)
    # log_sigma must come from the retained per-rep samples via the
    # MAD-robust scale (per point, then RMS across a cell's points):
    from collections import defaultdict
    import statistics
    by_key = defaultdict(list)
    for p in res.points:
        by_key[tuple(getattr(p, k, None) for k in ledger.CELL_KEY)].append(p)
    for cell in rec["curves"]:
        pts = by_key[tuple(cell[k] for k in ledger.CELL_KEY)]
        var = 0.0
        for p in pts:
            logs = [math.log(t) for t in p.rep_times_s]
            med = statistics.median(logs)
            mad = statistics.median(abs(x - med) for x in logs)
            var += (1.4826 * mad) ** 2
        want = math.sqrt(var / len(pts))
        assert cell["log_sigma"] == pytest.approx(want)


def test_cell_stats_sigma_robust_to_cold_rep():
    """A single 5x cold first rep must not deaden the gate: the MAD scale
    stays near the tight cluster's spread, not the outlier's."""
    from repro.bench.result import BenchPoint
    base = dict(mix="copy", nbytes=2**16, dtype="float32", backend="xla",
                passes=4, streams=1, block_rows=None, reps=5,
                bytes_per_call=2 * 2**16, flops_per_call=0,
                mean_s=1.2e-3, std_s=1e-3, min_s=6e-4, gbps=10.0, gflops=0.0)
    p = BenchPoint(**base, rep_times_s=(3.0e-3, 6.0e-4, 6.1e-4, 5.9e-4, 6.0e-4))
    cell = ledger._cell_stats([p])
    assert cell["log_sigma"] < 0.05   # plain std would be ~0.7
    # and with that sigma, a 1.5x drop at n=5 is well above the noise gate
    from repro.characterize.detect import significant_step
    assert significant_step(math.log(10.0), 5, math.log(10.0 / 1.5), 5,
                            sigma=cell["log_sigma"], z=3.0, min_drop=0.05)


# ---------------------------------------------------------------------------
# CLI: overwrite refusal, history, diff
# ---------------------------------------------------------------------------

def test_cli_refuses_silent_overwrite(tmp_path, capsys):
    from repro.bench.cli import main
    out = tmp_path / "r.json"
    out.write_text("{}")            # pre-existing artifact
    rc = main(["run", "--quick", "--mixes", "copy", "--sizes", "64K",
               "--reps", "2", "--out", str(out)])
    assert rc == 2
    assert "refusing to overwrite" in capsys.readouterr().err
    assert out.read_text() == "{}"          # untouched


def test_cli_force_overwrites_and_traces(tmp_path, capsys, monkeypatch):
    from repro.bench.cli import main
    monkeypatch.setenv(ledger.LEDGER_ENV, str(tmp_path / "hist"))
    out, tpath = tmp_path / "r.json", tmp_path / "t.json"
    out.write_text("{}")
    rc = main(["run", "--quick", "--mixes", "copy", "--sizes", "64K",
               "--reps", "2", "--out", str(out), "--force",
               "--trace", str(tpath)])
    assert rc == 0
    capsys.readouterr()
    res = json.loads(out.read_text())
    assert res["schema_version"] == 6 and res["meta"]["obs"]
    doc = json.loads(tpath.read_text())
    assert validate_chrome(doc) == []
    assert span_coverage(doc["traceEvents"]) >= 0.95
    # the run auto-appended a ledger record pointing at both artifacts
    [rec] = ledger.read_ledger()
    assert rec["cmd"] == "run"
    assert rec["out"] == str(out) and rec["trace"] == str(tpath)


def test_cli_history_and_diff_exit_codes(traced_run, tmp_path, capsys):
    from repro.bench.cli import main
    _, res = traced_run
    root = str(tmp_path / "hist")
    rfile = tmp_path / "res.json"
    res.to_json(rfile)
    assert main(["history", "--history-root", root]) == 0
    assert "empty ledger" in capsys.readouterr().out
    assert main(["history", "--add", str(rfile),
                 "--history-root", root]) == 0
    out = capsys.readouterr().out
    assert "ledger +=" in out and "copy,load_sum" in out
    # self-diff: exit 0
    assert main(["diff", "--baseline", "-1", "--history-root", root]) == 0
    capsys.readouterr()
    # perturbed baseline: every cell regresses, exit 2 (sigma pinned small —
    # the noise-absorption behavior is unit-tested elsewhere)
    rec = ledger.read_ledger(root)[0]
    for cell in rec["curves"]:
        cell["log_sigma"] = 0.02
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(rec))
    fast = json.loads(json.dumps(rec))
    for cell in fast["curves"]:
        cell["gbps"] *= 2.0
    fastp = tmp_path / "fast.json"
    fastp.write_text(json.dumps(fast))
    rc = main(["diff", "--baseline", str(fastp), "--current", str(cur),
               "--history-root", root])
    assert rc == 2
    captured = capsys.readouterr()
    assert "regression" in captured.out and "regression" in captured.err
    # unresolvable ref -> the CLI's uniform error exit, not a traceback
    assert main(["diff", "--baseline", "zzzz",
                 "--history-root", root]) == 2


def test_cli_no_ledger_skips_append(tmp_path, capsys, monkeypatch):
    from repro.bench.cli import main
    monkeypatch.setenv(ledger.LEDGER_ENV, str(tmp_path / "hist"))
    rc = main(["run", "--quick", "--mixes", "copy", "--sizes", "64K",
               "--reps", "2", "--no-ledger"])
    assert rc == 0
    capsys.readouterr()
    assert ledger.read_ledger() == []
