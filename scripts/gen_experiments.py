"""Generate the data-driven sections of EXPERIMENTS.md from artifacts/."""
import glob
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "artifacts" / "dryrun"
PROBE = ROOT / "artifacts" / "probe"


def load_cells(variant="baseline"):
    cells = {}
    for f in sorted(glob.glob(str(DRY / f"*__{variant}.json"))):
        d = json.loads(Path(f).read_text())
        key = (d["arch"], d["shape"], d.get("multi_pod", False))
        cells[key] = {"dry": d}
    for f in sorted(glob.glob(str(PROBE / f"*__{variant}.json"))):
        d = json.loads(Path(f).read_text())
        key = (d["arch"], d["shape"], d.get("multi_pod", False))
        cells.setdefault(key, {})["probe"] = d
    return cells


def dryrun_table(cells) -> str:
    hdr = ("| arch | shape | mesh | status | compile s | peak GiB/dev | fits "
           "16 GiB | collectives (count) |")
    out = [hdr, "|" + "---|" * 8]
    for (arch, shape, mp), c in sorted(cells.items()):
        d = c.get("dry")
        if d is None:
            continue
        mesh = "2x16x16" if mp else "16x16"
        if d["status"] == "skipped":
            out.append(f"| {arch} | {shape} | {mesh} | skipped "
                       f"(sub-quadratic-only shape) | - | - | - | - |")
            continue
        if d["status"] != "ok":
            out.append(f"| {arch} | {shape} | {mesh} | **ERROR** | - | - | - "
                       f"| {d['error'][:40]} |")
            continue
        colls = d.get("collective_breakdown", {})
        cstr = ", ".join(f"{k}x{v['count']}" for k, v in sorted(colls.items()))
        out.append(
            f"| {arch} | {shape} | {mesh} | ok | {d.get('compile_s','-')} "
            f"| {d['peak_device_bytes']/2**30:.2f} "
            f"| {'yes' if d.get('fits_hbm') else 'NO'} | {cstr} |")
    return "\n".join(out)


def roofline_table(cells) -> str:
    hdr = ("| arch | shape | mesh | t_comp s | t_mem s | t_coll s | dominant "
           "| useful flops | roofline frac | bottleneck note |")
    out = [hdr, "|" + "---|" * 10]
    notes = {
        "compute": "MXU-bound: raise intensity (folded attn, fused kernels)",
        "memory": "HBM-bound: cut bytes (bf16/fp8 state, cache layout)",
        "collective": "ICI-bound: cut wire bytes (bf16 gathers/psum, overlap)",
    }
    for (arch, shape, mp), c in sorted(cells.items()):
        p = c.get("probe")
        if p is None or p.get("status") != "ok":
            continue
        mesh = "2x16x16" if mp else "16x16"
        terms = {"compute": p["t_compute_s"], "memory": p["t_memory_s"],
                 "collective": p["t_collective_s"]}
        dom = p["dominant"]
        # roofline fraction: ideal compute time / achievable step time (sum of
        # the two non-overlappable worst terms ~ max as optimistic bound)
        step = max(terms.values())
        frac = p["model_flops"] / 197e12 / step if step else 0
        out.append(
            f"| {arch} | {shape} | {mesh} | {terms['compute']:.4f} "
            f"| {terms['memory']:.4f} | {terms['collective']:.4f} | **{dom}** "
            f"| {min(p.get('useful_flop_ratio', 0), 9.99):.2f} "
            f"| {frac:.2f} | {notes[dom]} |")
    return "\n".join(out)


if __name__ == "__main__":
    cells = load_cells(sys.argv[1] if len(sys.argv) > 1 else "baseline")
    print("### Dry-run table\n")
    print(dryrun_table(cells))
    print("\n### Roofline table\n")
    print(roofline_table(cells))
