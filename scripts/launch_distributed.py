#!/usr/bin/env python
"""Launch a multi-process (simulated multi-host) benchmark on one machine.

Thin shell over ``repro.bench.distributed.launch_local`` — the same engine
behind ``python -m repro.bench launch``; this script exists so cluster entry
points / schedulers that expect a file path (not ``-m``) have one.

    # 2 simulated hosts x 2 forced host devices = a 4-device global mesh
    python scripts/launch_distributed.py --processes 2 --devices-per-process 2 \
        -- --devices 4 --mixes load_sum,copy --sizes 2M --reps 2 --out out.json

Everything after ``--`` is forwarded verbatim to each worker's
``python -m repro.bench run --backend distributed``; process 0 writes the
gathered result.  On a real cluster skip this launcher entirely: start one
process per host with REPRO_COORDINATOR / REPRO_NUM_PROCESSES /
REPRO_PROCESS_ID set and run the same ``run`` command everywhere.
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
sys.path.insert(0, SRC)


def main(argv=None) -> int:
    # allow_abbrev: a pre-`--` `--devices N` must error loudly, not silently
    # match --devices-per-process (the prefix bug fixed in bench.cli)
    ap = argparse.ArgumentParser(description=__doc__, allow_abbrev=False,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=1)
    ap.add_argument("--backend", default="distributed")
    ap.add_argument("--timeout", type=float, default=None)
    ap.add_argument("worker_flags", nargs=argparse.REMAINDER,
                    help="flags after -- go to `repro.bench run` verbatim")
    args = ap.parse_args(argv)
    flags = [f for f in args.worker_flags if f != "--"]

    # the workers (`python -m repro.bench`) must import repro like this
    # script does: propagate the src dir into their PYTHONPATH
    paths = os.environ.get("PYTHONPATH", "")
    if SRC not in paths.split(os.pathsep):
        os.environ["PYTHONPATH"] = (f"{SRC}{os.pathsep}{paths}" if paths
                                    else SRC)

    # one launch implementation: delegate to the CLI's `launch` (it owns the
    # full-mesh --devices default and the worker-argv assembly)
    from repro.bench.cli import main as bench_main
    launch = ["launch", "--processes", str(args.processes),
              "--devices-per-process", str(args.devices_per_process),
              "--backend", args.backend]
    if args.timeout is not None:
        launch += ["--timeout", str(args.timeout)]
    return bench_main(launch + flags)


if __name__ == "__main__":
    sys.exit(main())
